"""Lane-batched campaign engine: lane-vs-scalar equivalence, batched
Phase-2 profiling, dense-rate precompute, and the optimize_plan
simulate-to-verify pass."""
import numpy as np
import pytest

from repro.config import CheckpointPlan
from repro.core import (QoSModel, optimize_plan, run_profiling,
                        run_profiling_campaign, select_failure_points)
from repro.data.stream import (constant_rate, dense_rates, diurnal_rate,
                               record_workload)
from repro.ft.failures import Degradation, FailureInjector
from repro.sim import (BatchedCampaign, BatchedDeployment, LaneSpec,
                       SimCostModel, SimDeployment, StreamSimulator,
                       make_plan_verifier, measure_profile_lanes)

COST = SimCostModel(capacity_eps=4600.0, base_latency_s=0.5,
                    ckpt_duration_s=3.0, ckpt_sync_penalty=0.6)
PLANS = [
    None,                                              # full-sync default
    CheckpointPlan(sync=False),                        # full-async
    CheckpointPlan(mode="incremental", full_every=8, sync=False),
    CheckpointPlan(mode="incremental", full_every=4,   # multi-level delta
                   levels=("memory", "local", "remote"),
                   local_every=1, remote_every=8),
]
KINDS = ("task", "node", "cluster")


def _worst_case(ci):
    return FailureInjector().worst_case_time(3 * ci + 5.0, 0.0, ci,
                                             COST.ckpt_duration_s)


def _scalar_twin(ci, plan, kind, inject_t, t_end, schedule):
    sim = StreamSimulator(COST, ci_s=ci, schedule=schedule, plan=plan)
    sim.inject_failure(inject_t, kind)
    sim.run_until(t_end)
    return sim


def test_lane_matches_scalar_across_plans_and_kinds():
    """Fixed-seed campaign: every lane's full lag trajectory, recovery time
    and conservation totals match its scalar StreamSimulator twin exactly —
    multi-level delta plans and all three failure kinds included."""
    T = 4000
    sched = constant_rate(3000.0)
    lanes, scalars = [], []
    for ci in (30.0, 90.0):
        for plan in PLANS:
            for kind in KINDS:
                t = _worst_case(ci)
                scalars.append(_scalar_twin(ci, plan, kind, t, T, sched))
                lanes.append(LaneSpec(
                    rates=dense_rates(0.0, T, schedule=sched),
                    ci_s=ci, plan=plan, failures=((t, kind),)))
    camp = BatchedCampaign(COST, lanes).run()
    for i, sim in enumerate(scalars):
        lag_scalar = np.array(sim.metrics.series("consumer_lag").values)
        np.testing.assert_array_equal(lag_scalar, camp.lag_hist[i],
                                      err_msg=f"lane {i} lag diverged")
        rec_scalar = sim.recoveries[0]["recovery_s"] if sim.recoveries else None
        assert camp.lane_recovery(i) == rec_scalar, f"lane {i} recovery"
        assert camp.produced[i] == sim.produced
        assert camp.consumed[i] == sim.consumed
        assert camp.ckpt_count[i] == sim.ckpt_count
        if sim.recoveries:
            r_s, r_b = sim.recoveries[0], camp.recoveries[i][0]
            assert r_b["kind"] == r_s["kind"]
            assert r_b["restore_level"] == r_s["restore_level"]
            assert r_b["plan"] == r_s["plan"]


def test_lane_matches_scalar_on_real_valued_schedule():
    """Non-integer λ(t) exercises every FP rounding in the rollback path —
    the batched tick must keep the scalar's association order exactly."""
    sched = diurnal_rate(base=2800, amplitude=0.5, period=5400, seed=13)
    T = 3000
    for ci, kind in ((25.0, "node"), (70.0, "cluster")):
        t = _worst_case(ci)
        sim = _scalar_twin(ci, PLANS[3], kind, t, T, sched)
        lane = LaneSpec(rates=dense_rates(0.0, T, schedule=sched), ci_s=ci,
                        plan=PLANS[3], failures=((t, kind),))
        camp = BatchedCampaign(COST, [lane]).run()
        np.testing.assert_array_equal(
            np.array(sim.metrics.series("consumer_lag").values),
            camp.lag_hist[0])
        rec = sim.recoveries[0]["recovery_s"] if sim.recoveries else None
        assert camp.lane_recovery(0) == rec
        assert camp.produced[0] == sim.produced
        assert camp.consumed[0] == sim.consumed


def test_lane_matches_scalar_on_recording_with_offset_clock():
    """Recording-driven lane starting at t0 > 0 (the Phase-2 shape)."""
    sched = diurnal_rate(base=2600, amplitude=0.4, period=7200, seed=5)
    rec = record_workload(sched, duration=7200, seed=5)
    t0, ci = 1000.0, 45.0
    inject_t = FailureInjector().worst_case_time(1500.0, t0, ci,
                                                COST.ckpt_duration_s)
    t_end = 4000.0
    sim = StreamSimulator(COST, ci_s=ci, recording=rec, t0=t0)
    sim.inject_failure(inject_t)
    sim.run_until(t_end)
    n = int(t_end - t0)
    lane = LaneSpec(rates=rec.rates_until(t_end, t0=t0), ci_s=ci, t0=t0,
                    failures=((inject_t, "node"),))
    camp = BatchedCampaign(COST, [lane]).run()
    assert camp.lane_ticks[0] == n
    np.testing.assert_array_equal(
        np.array(sim.metrics.series("consumer_lag").values),
        camp.lag_hist[0][:n])
    rec_scalar = sim.recoveries[0]["recovery_s"] if sim.recoveries else None
    assert camp.lane_recovery(0) == rec_scalar


def test_batched_profiling_matches_sequential_deployments():
    """run_profiling_campaign == run_profiling(SimDeployment) on the same
    (CI x failure point) grid — the sequential-deployments deviation is
    closed without changing the statistics."""
    sched = diurnal_rate(base=1500, amplitude=0.4, period=7200, seed=3)
    rec = record_workload(sched, duration=7200, seed=3)
    ss = select_failure_points(rec, m=3, smoothing_window=30)
    cost = SimCostModel(capacity_eps=2600.0, ckpt_duration_s=1.5)
    cis = [30, 240]
    seq = run_profiling(
        lambda ci: SimDeployment(ci, rec, cost, warmup_s=200,
                                 max_recovery_s=3600.0),
        ss, cis, margin=60)
    bat = run_profiling_campaign(
        BatchedDeployment(cost, rec, warmup_s=200, max_recovery_s=3600.0),
        ss, cis, margin=60)
    np.testing.assert_allclose(bat.latencies, seq.latencies, atol=1e-9)
    np.testing.assert_allclose(bat.recoveries, seq.recoveries, atol=1e-9)
    # and the premise survives: recovery grows with CI on average
    assert bat.recoveries[:, 1].mean() > bat.recoveries[:, 0].mean()


def test_dense_rates_matches_per_tick_calls():
    sched = diurnal_rate(base=1200, amplitude=0.5, period=3600, seed=2)
    rec = record_workload(sched, duration=600, seed=2)
    t0, n = 37.0, 400
    dense_sched = dense_rates(t0, n, schedule=sched)
    dense_rec = dense_rates(t0, n, recording=rec)
    for k in (0, 1, 57, 399):
        t = t0 + float(k)
        assert dense_sched[k] == sched(t)
        assert dense_rec[k] == rec.rate_at(t)
    np.testing.assert_array_equal(rec.rates_until(t0 + n, t0=t0), dense_rec)


def test_scalar_sim_rate_buffer_matches_rate_at():
    """The buffered tick-loop λ equals the per-tick rate_at call."""
    sched = diurnal_rate(base=900, amplitude=0.6, period=1800, seed=11)
    sim = StreamSimulator(SimCostModel(capacity_eps=2000.0), ci_s=60.0,
                          schedule=sched, t0=13.0)
    sim.run_until(13.0 + 500)
    ts = np.array(sim.metrics.series("arrival_rate").times)
    vs = np.array(sim.metrics.series("arrival_rate").values)
    assert len(ts) == 500
    for t, v in zip(ts[::37], vs[::37]):
        assert v == sim.rate_at(t)


@pytest.mark.tier1
def test_campaign_smoke():
    """Fast gate: a small mixed campaign runs end-to-end, conserves events
    on failure-free lanes and measures recovery on the chaos lanes."""
    T = 1200
    sched = constant_rate(2000.0)
    cost = SimCostModel(capacity_eps=3000.0, ckpt_duration_s=1.0)
    t = FailureInjector().worst_case_time(150.0, 0.0, 30.0, 1.0)
    lanes = [
        LaneSpec(rates=dense_rates(0.0, T, schedule=sched), ci_s=30.0),
        LaneSpec(rates=dense_rates(0.0, T, schedule=sched), ci_s=30.0,
                 failures=((t, "node"),)),
        LaneSpec(rates=dense_rates(0.0, T, schedule=sched), ci_s=60.0,
                 plan=CheckpointPlan(sync=False), failures=((t, "task"),)),
        LaneSpec(rates=dense_rates(0.0, T, schedule=sched), ci_s=60.0,
                 plan=PLANS[3], failures=((t, "cluster"),)),
    ]
    camp = BatchedCampaign(cost, lanes).run()
    # failure-free lane: produced == consumed + lag (no rollback)
    assert abs(camp.produced[0] - (camp.consumed[0] + camp.lag[0])) < 1e-6
    assert camp.ckpt_count[0] >= 30
    for i in (1, 2, 3):
        assert camp.lane_recovery(i) is not None, f"lane {i} never recovered"
        assert camp.lane_recovery(i) > cost.downtime_s()
    # multi-level plan survives the cluster failure via the remote level
    assert camp.recoveries[3][0]["restore_level"] == "remote"
    assert camp.ticks_run == 4 * T


def test_mixed_horizon_compaction_is_invisible_to_results():
    """Terminal lanes are compacted out of the array state mid-run; every
    result (lag trajectories, recoveries, conservation, tick accounting)
    still matches the scalar oracle lane-for-lane."""
    sched = constant_rate(3000.0)
    horizons = (500, 4000, 700, 2500, 900, 1400)
    lanes, scalars = [], []
    for j, T in enumerate(horizons):
        ci = 30.0 + 15 * j
        t = _worst_case(ci)
        lanes.append(LaneSpec(rates=dense_rates(0.0, T, schedule=sched),
                              ci_s=ci, failures=((t, "node"),)))
        scalars.append(_scalar_twin(ci, None, "node", t, T, sched))
    camp = BatchedCampaign(COST, lanes, compact_every=64).run()
    assert camp.compactions > 0, "mixed horizons must trigger compaction"
    assert camp.ticks_run == sum(horizons)
    for i, sim in enumerate(scalars):
        lag_scalar = np.array(sim.metrics.series("consumer_lag").values)
        np.testing.assert_array_equal(lag_scalar,
                                      camp.lag_hist[i][:len(lag_scalar)])
        rec = sim.recoveries[0]["recovery_s"] if sim.recoveries else None
        assert camp.lane_recovery(i) == rec
        assert camp.produced[i] == sim.produced
        assert camp.consumed[i] == sim.consumed
        assert camp.ckpt_count[i] == sim.ckpt_count


def test_early_exit_retires_recovered_lanes():
    """early_exit=True retires chaos-resolved lanes before their horizon:
    fewer lane-ticks executed, identical recovery measurements."""
    sched = constant_rate(3000.0)
    T = 4000
    lanes, scalars = [], []
    for ci in (20.0, 40.0, 60.0, 80.0):
        t = _worst_case(ci)
        lanes.append(LaneSpec(rates=dense_rates(0.0, T, schedule=sched),
                              ci_s=ci, failures=((t, "node"),)))
        scalars.append(_scalar_twin(ci, None, "node", t, T, sched))
    camp = BatchedCampaign(COST, lanes, record_history=False,
                           early_exit=True, compact_every=64).run()
    assert camp.done
    assert camp.lanes_compacted == len(lanes)
    assert camp.ticks_run < len(lanes) * T, "no lane exited early"
    for i, sim in enumerate(scalars):
        assert camp.lane_recovery(i) == sim.recoveries[0]["recovery_s"]
    # failure-free lanes are never early-exited (nothing was "resolved")
    camp2 = BatchedCampaign(
        COST, [LaneSpec(rates=dense_rates(0.0, 1200, schedule=sched),
                        ci_s=30.0)],
        record_history=False, early_exit=True, compact_every=64).run()
    assert camp2.ticks_run == 1200


def test_optimize_plan_simulate_to_verify():
    """The verifier replays the surface top-k and re-ranks by measured
    objective; replayed candidates carry their measurement."""
    cost = SimCostModel(capacity_eps=2600.0, ckpt_duration_s=1.5)
    rng = np.random.default_rng(0)
    ci = rng.uniform(10, 120, 200)
    tr = rng.uniform(1000, 2200, 200)
    m_l = QoSModel().fit(ci, tr, cost.base_latency_s + 40.0 / ci + tr * 1e-5)
    m_r = QoSModel().fit(ci, tr, 80.0 + 1.2 * ci + 0.01 * tr)
    calls = []
    real = make_plan_verifier(cost, schedule=constant_rate(1500.0),
                              warmup_s=120, max_recovery_s=1200.0)

    def verifier(cands):
        calls.append(list(cands))
        return real(cands)

    res = optimize_plan(m_l, m_r, tr_avg=1500.0, l_const=2.0, r_const=600.0,
                        p=1.0, ci_min=10, ci_max=120, cost=cost,
                        verifier=verifier, verify_top_k=2)
    assert res.verified and res.feasible
    assert len(calls) == 1 and len(calls[0]) == 2
    replayed = [c for c in res.candidates if c.sim is not None]
    assert len(replayed) == 2
    for c in replayed:
        assert {"latency_s", "recovery_s", "objective", "feasible"} <= set(c.sim)
    # the chosen plan is one of the replayed shortlist
    assert res.plan.name in {c.plan.name for c in replayed} or res.plan is None


DEGRADATIONS = {
    "straggler": [Degradation(t=300.0, kind="straggler", duration_s=400.0,
                              severity=1.8)],
    "net_delay_source": [Degradation(t=250.0, kind="net_delay",
                                     duration_s=500.0, severity=3.0,
                                     jitter_s=0.8, direction="to_source")],
    "net_delay_store": [Degradation(t=250.0, kind="net_delay",
                                    duration_s=600.0, severity=4.0,
                                    jitter_s=1.0,
                                    direction="to_ckpt_store")],
    "backpressure": [Degradation(t=200.0, kind="backpressure",
                                 duration_s=150.0)],
}


def _scalar_degraded(ci, plan, degs, failures, t_end, sched):
    sim = StreamSimulator(COST, ci_s=ci, schedule=sched, plan=plan)
    for d in degs:
        sim.inject_degradation(d.t, d.kind, d.duration_s, severity=d.severity,
                               jitter_s=d.jitter_s, direction=d.direction)
    for (ft, kind) in failures:
        sim.inject_failure(ft, kind)
    sim.run_until(t_end)
    return sim


def test_lane_matches_scalar_under_degradations():
    """Bit-exact lane-vs-scalar parity for all three gray-failure kinds
    (both net_delay directions), alone and composed with a crash, on a
    real-valued diurnal λ(t): full lag AND latency trajectories, event
    conservation, suppressed-trigger counts and recovery records."""
    T = 2500
    sched = diurnal_rate(base=2800, amplitude=0.5, period=5400, seed=7)
    lanes, scalars = [], []
    for ci in (30.0, 75.0):
        for plan in (None, PLANS[3]):
            for name, degs in DEGRADATIONS.items():
                for failures in ((), ((_worst_case(ci) + 400.0, "node"),)):
                    scalars.append(_scalar_degraded(ci, plan, degs,
                                                    failures, T, sched))
                    lanes.append(LaneSpec(
                        rates=dense_rates(0.0, T, schedule=sched), ci_s=ci,
                        plan=plan, failures=failures, degradations=degs,
                        tag={"deg": name}))
    camp = BatchedCampaign(COST, lanes).run()
    lat_hist = camp.latency_history()
    for i, sim in enumerate(scalars):
        name = lanes[i].tag["deg"]
        np.testing.assert_array_equal(
            np.array(sim.metrics.series("consumer_lag").values),
            camp.lag_hist[i], err_msg=f"lane {i} ({name}) lag diverged")
        np.testing.assert_array_equal(
            np.array(sim.metrics.series("latency").values),
            lat_hist[i], err_msg=f"lane {i} ({name}) latency diverged")
        assert camp.produced[i] == sim.produced
        assert camp.consumed[i] == sim.consumed
        assert camp.ckpt_count[i] == sim.ckpt_count
        assert camp.bp_suppressed[i] == sim.bp_suppressed
        rec = sim.recoveries[0]["recovery_s"] if sim.recoveries else None
        assert camp.lane_recovery(i) == rec, f"lane {i} ({name}) recovery"


def test_degradation_semantics_are_gray_not_crashes():
    """Degradations bend dynamics without killing the job: a straggler
    window builds lag then drains; backpressure suppresses triggers and
    inflates lost work at the next crash; to-store delay stretches
    checkpoints; to-source delay inflates latency but not lag."""
    T = 2000
    sched = constant_rate(3000.0)

    base = _scalar_degraded(30.0, None, [], (), T, sched)
    strag = _scalar_degraded(30.0, None, DEGRADATIONS["straggler"], (),
                             T, sched)
    assert not strag.recoveries and strag.down_until is None
    # capacity dips below λ inside the window: lag peaks, then drains back
    lag = np.array(strag.metrics.series("consumer_lag").values)
    assert lag[300:700].max() > 100.0 and lag[-1] <= lag[300:700].max()
    assert strag.ckpt_count > 0

    bp = _scalar_degraded(30.0, None, DEGRADATIONS["backpressure"],
                          ((340.0, "node"),), T, sched)
    ref = _scalar_degraded(30.0, None, [], ((340.0, "node"),), T, sched)
    assert bp.bp_suppressed > 0 and ref.bp_suppressed == 0
    # the barrier slipped past its slot: fewer checkpoints, and the crash
    # right after the window replays more work than the undegraded twin
    assert bp.ckpt_count < ref.ckpt_count
    assert bp.recoveries[0]["recovery_s"] > ref.recoveries[0]["recovery_s"]

    store = _scalar_degraded(30.0, None, DEGRADATIONS["net_delay_store"], (),
                             T, sched)
    # stretched barrier writes: longer sync pauses build more lag inside
    # the window than the undegraded twin
    lag_store = np.array(store.metrics.series("consumer_lag").values)
    lag_base = np.array(base.metrics.series("consumer_lag").values)
    assert lag_store[260:860].mean() > lag_base[260:860].mean() * 1.5

    src = _scalar_degraded(30.0, None, DEGRADATIONS["net_delay_source"], (),
                           T, sched)
    lat = np.array(src.metrics.series("latency").values)
    lat0 = np.array(base.metrics.series("latency").values)
    assert lat[260:740].mean() > lat0[260:740].mean() + 1.0
    np.testing.assert_array_equal(
        np.array(src.metrics.series("consumer_lag").values),
        np.array(base.metrics.series("consumer_lag").values))


def test_unknown_kind_rejected_everywhere():
    """The closed-KINDS contract: unknown kinds raise at every entry."""
    sim = StreamSimulator(COST, ci_s=30.0, schedule=constant_rate(100.0))
    with pytest.raises(ValueError, match="unknown crash kind"):
        sim.inject_failure(10.0, "gray_goo")
    with pytest.raises(ValueError, match="unknown degradation kind"):
        sim.inject_degradation(10.0, "node", 50.0)
    with pytest.raises(ValueError, match="unknown direction"):
        Degradation(t=0.0, kind="net_delay", duration_s=10.0,
                    direction="sideways")


def test_campaign_scales_to_large_grids():
    """>= 200 lanes advance in one sweep and every lane stays independent
    (spot-check a lane in the middle against its scalar twin)."""
    T = 1500
    sched = constant_rate(3000.0)
    lanes = []
    for ci in np.geomspace(10, 240, 18):
        for plan in PLANS:
            for kind in KINDS:
                t = _worst_case(float(ci))
                lanes.append(LaneSpec(rates=dense_rates(0.0, T, schedule=sched),
                                      ci_s=float(ci), plan=plan,
                                      failures=((t, kind),)))
    assert len(lanes) >= 200
    camp = BatchedCampaign(COST, lanes).run()
    assert camp.ticks_run == len(lanes) * T
    i = 101
    spec = lanes[i]
    sim = _scalar_twin(spec.ci_s, spec.plan, KINDS[101 % 3],
                       spec.failures[0][0], T, sched)
    np.testing.assert_array_equal(
        np.array(sim.metrics.series("consumer_lag").values),
        camp.lag_hist[i])


def test_measure_profile_lanes_vectorized_matches_loop_reference():
    """The one-pass NumPy recovery scan must reproduce the per-lane Python
    reference bit-for-bit — including lanes with no pre-window samples, no
    post-injection ticks, and unrecovered lanes hitting max_recovery_s."""
    from repro.sim.batched import _measure_profile_lanes_loop
    T = 1400
    sched = diurnal_rate(base=2400.0, amplitude=0.4, period=3600.0, seed=3)
    lanes, injects = [], []
    for j, ci in enumerate(np.geomspace(12.0, 180.0, 9)):
        t = _worst_case(float(ci))
        lanes.append(LaneSpec(rates=dense_rates(0.0, T - 40 * (j % 3),
                                                schedule=sched),
                              ci_s=float(ci), t0=0.0,
                              failures=((t, KINDS[j % 3]),)))
        injects.append(t)
    # degenerate injections: before any pre-window, after the horizon
    lanes.append(LaneSpec(rates=dense_rates(0.0, T, schedule=sched),
                          ci_s=30.0))
    injects.append(0.0)                   # pre-window empty
    lanes.append(LaneSpec(rates=dense_rates(0.0, T, schedule=sched),
                          ci_s=30.0))
    injects.append(float(T + 100))        # no post-injection ticks
    camp = BatchedCampaign(COST, lanes).run()
    for margin, max_rec in ((90.0, 900.0), (60.0, 50.0)):
        fast = measure_profile_lanes(camp, injects, margin, max_rec)
        ref = _measure_profile_lanes_loop(camp, injects, margin, max_rec)
        assert fast == ref
    # the pooled-slice path (explicit lanes=) must agree too
    sel = [3, 7, 10]
    fast = measure_profile_lanes(camp, [injects[i] for i in sel], 90.0,
                                 900.0, lanes=sel)
    ref = _measure_profile_lanes_loop(camp, [injects[i] for i in sel],
                                      90.0, 900.0, lanes=sel)
    assert fast == ref


def test_handles_survive_compaction_and_retired_actuation_is_inert():
    """A live BatchedLaneHandle must keep observing its lane after
    compaction retires it (reads route through the _final masters), and
    actuating a retired lane is a no-op instead of a crash — so pooled
    fleet campaigns can compact under live supervisors."""
    from repro.sim import BatchedLaneHandle
    sched = constant_rate(3000.0)
    # lane 0 recovers and early-exits; lane 1 (no chaos) must run out its
    # longer horizon, keeping the campaign alive past the compaction
    lanes = [LaneSpec(rates=dense_rates(0.0, 500, schedule=sched), ci_s=30.0,
                      failures=((_worst_case(30.0), "node"),)),
             LaneSpec(rates=dense_rates(0.0, 2000, schedule=sched),
                      ci_s=30.0)]
    camp = BatchedCampaign(COST, lanes, early_exit=True, compact_every=64)
    h_short, h_long = (BatchedLaneHandle(camp, i) for i in range(2))
    camp.run(n_ticks=1200)
    assert camp.compactions > 0 and camp._pos[0] < 0, \
        "scenario must retire lane 0 mid-run while lane 1 lives"
    # retired lane: reads still work, actuation is inert
    assert not h_short.alive()
    t_frozen = h_short.now()
    ci_frozen = h_short.current_ci()
    camp.lane_set_ci(0, 15.0)
    camp.lane_set_plan(0, CheckpointPlan(sync=False))
    assert h_short.current_ci() == ci_frozen
    assert h_short.current_plan().interval_s == ci_frozen
    assert h_short.now() == t_frozen
    # live lane: actuation still lands post-compaction
    assert h_long.alive()
    camp.lane_set_ci(1, 20.0)
    assert h_long.current_ci() == 20.0
    camp.run()
    assert camp.done
    rec = camp.recoveries[0]
    assert rec, "retired lane keeps its recovery record"
