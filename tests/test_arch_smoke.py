"""Per-architecture smoke tests (deliverable f): a REDUCED same-family
config per assigned arch runs one train step + prefill + decode on CPU,
asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import OptimizerConfig, ShapeConfig
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import zoo
from repro.optim import make_optimizer

B, S = 2, 16


def _make_batch(cfg, rng, mode):
    shape = ShapeConfig("t", mode, S, B)
    specs = zoo.input_specs(cfg, shape)
    batch = {}
    for k, v in specs.items():
        if v.dtype == jnp.int32:
            batch[k] = jax.random.randint(rng, v.shape, 0, cfg.vocab_size)
        else:
            batch[k] = jax.random.normal(rng, v.shape, v.dtype) * 0.02
    if "positions" in specs:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    if "vision_embeds" in specs:
        nv = S // 2
        batch["vision_embeds"] = jax.random.normal(
            rng, (B, nv, cfg.d_model), specs["vision_embeds"].dtype) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    rng = jax.random.PRNGKey(0)
    params = zoo.init_params(cfg, rng)
    opt_cfg = OptimizerConfig(total_steps=10)
    opt = make_optimizer(opt_cfg)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}

    # train step
    batch = _make_batch(cfg, rng, "train")
    step = jax.jit(zoo.make_train_step(cfg, opt, opt_cfg))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2["step"]) == 1
    for leaf in jax.tree_util.tree_leaves(state2["params"]):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))

    # prefill
    pre_in = {k: v for k, v in _make_batch(cfg, rng, "prefill").items()}
    prefill = jax.jit(zoo.make_prefill_step(cfg))
    next_tok, caches = prefill(params, pre_in)
    assert next_tok.shape == (B,)
    assert int(next_tok.max()) < cfg.padded_vocab

    # decode one token continuing from prefill
    decode = jax.jit(zoo.make_decode_step(cfg))
    last = (S // cfg.dec_ratio - 1) if cfg.family == "audio" else (S - 1)
    tok2, caches2 = decode(params, caches,
                           {"tokens": next_tok[:, None],
                            "pos": jnp.full((B,), last, jnp.int32)})
    assert tok2.shape == (B, 1)
    for leaf in jax.tree_util.tree_leaves(caches2):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_metadata(arch):
    """The FULL configs (exercised via dry-run only) are well-formed."""
    cfg = get_config(arch)
    assert cfg.param_count() > 1e8
    assert cfg.padded_vocab % cfg.vocab_pad_multiple == 0
    assert cfg.resolved_head_dim * cfg.num_heads >= cfg.d_model or cfg.family == "ssm"
    if cfg.family == "moe":
        assert cfg.active_param_count() < cfg.param_count()
    else:
        assert cfg.active_param_count() == cfg.param_count()


def test_decode_matches_prefill_dense():
    """Step-by-step decode reproduces prefill's next-token prediction."""
    cfg = get_smoke_config("yi-6b")
    rng = jax.random.PRNGKey(3)
    params = zoo.init_params(cfg, rng)
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    prefill = jax.jit(zoo.make_prefill_step(cfg))
    next_ref, _ = prefill(params, {"tokens": tokens})

    # decode from scratch: feed tokens one at a time into empty caches
    decode = jax.jit(zoo.make_decode_step(cfg))
    L, K, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    caches = {"k": jnp.zeros((L, B, S, K, hd), jnp.bfloat16),
              "v": jnp.zeros((L, B, S, K, hd), jnp.bfloat16)}
    for t in range(S):
        tok, caches = decode(params, caches,
                             {"tokens": tokens[:, t:t + 1],
                              "pos": jnp.full((B,), t, jnp.int32)})
    np.testing.assert_array_equal(np.asarray(tok[:, 0]), np.asarray(next_ref))
