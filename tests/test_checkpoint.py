"""Checkpoint subsystem: atomicity, resharding, async, incremental, multilevel."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, CheckpointPolicy,
                              CheckpointStore, IncrementalCheckpointer,
                              MultiLevelCheckpointer)
from repro.utils.trees import tree_allclose


def _state(seed=0, n=1000):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w1": rng.standard_normal((n, 8)).astype(np.float32),
                   "w2": rng.standard_normal((n,)).astype(np.float32)},
        "opt": {"m": {"w1": rng.standard_normal((n, 8)).astype(np.float32),
                      "w2": np.zeros((n,), np.float32)}},
        "step": np.int32(7),
    }


def test_store_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path), num_shards=3)
    s = _state()
    store.save(7, s, timestamp=1.0, extra={"cursor": 42})
    restored, extra = store.restore(s)
    assert tree_allclose(s, restored)
    assert extra["cursor"] == 42


def test_store_reshard_restore_across_host_counts(tmp_path):
    """Save with 8 shards, restore through a store configured for 2 —
    manifest-driven restore is shard-count agnostic (elastic rescale)."""
    s = _state(1)
    CheckpointStore(str(tmp_path), num_shards=8).save(3, s)
    restored, _ = CheckpointStore(str(tmp_path), num_shards=2).restore(s)
    assert tree_allclose(s, restored)


def test_store_atomicity_corrupt_shard_falls_back(tmp_path):
    store = CheckpointStore(str(tmp_path), num_shards=2, keep=5)
    s1, s2 = _state(1), _state(2)
    store.save(1, s1)
    store.save(2, s2)
    # corrupt the newest checkpoint's shard
    p = os.path.join(str(tmp_path), "step_0000000002", "shard_00000.npz")
    with open(p, "r+b") as f:
        f.seek(10)
        f.write(b"\x00\x00\x00\x00")
    assert store.newest() == 1          # checksum mismatch hides step 2
    restored, _ = store.restore(s1)
    assert tree_allclose(s1, restored)


def test_store_missing_manifest_invisible(tmp_path):
    store = CheckpointStore(str(tmp_path), num_shards=1)
    store.save(5, _state())
    os.remove(os.path.join(str(tmp_path), "step_0000000005", "manifest.json"))
    assert store.newest() is None


def test_store_gc_keeps_newest(tmp_path):
    store = CheckpointStore(str(tmp_path), num_shards=1, keep=2)
    for step in [1, 2, 3, 4]:
        store.save(step, _state(step))
    assert store.list_steps() == [3, 4]


def test_async_checkpointer_writes_and_skips(tmp_path):
    store = CheckpointStore(str(tmp_path), num_shards=1)
    ac = AsyncCheckpointer(store, busy_policy="skip")
    s = _state()
    assert ac.save(1, s)
    ac.wait()
    assert store.newest() == 1
    assert not ac.errors


def test_async_snapshot_isolation(tmp_path):
    """Mutating the live state after save() must not affect the snapshot."""
    store = CheckpointStore(str(tmp_path), num_shards=1)
    ac = AsyncCheckpointer(store)
    s = {"w": np.ones(10, np.float32)}
    ac.save(1, s)
    s["w"][:] = 999.0
    ac.wait()
    restored, _ = store.restore({"w": np.zeros(10, np.float32)})
    assert np.allclose(restored["w"], 1.0)


@pytest.mark.parametrize("mode", ["lossless", "int8"])
def test_incremental_roundtrip(tmp_path, mode):
    store = CheckpointStore(str(tmp_path), num_shards=2)
    inc = IncrementalCheckpointer(store, full_every=4, mode=mode)
    s = _state(3)
    inc.save(0, s)
    s2 = jax.tree_util.tree_map(
        lambda x: x + np.float32(0.01) if x.dtype == np.float32 else x, s)
    inc.save(1, s2)
    restored, step = inc.restore(s)
    assert step == 1
    if mode == "lossless":
        assert tree_allclose(s2, restored, rtol=1e-6, atol=1e-6)
    else:
        for a, b in zip(jax.tree_util.tree_leaves(s2),
                        jax.tree_util.tree_leaves(restored)):
            if a.dtype == np.float32:
                assert np.max(np.abs(a - b)) < 1e-3


def test_incremental_delta_smaller_than_full(tmp_path):
    store = CheckpointStore(str(tmp_path), num_shards=1)
    inc = IncrementalCheckpointer(store, full_every=4, mode="lossless")
    s = _state(4, n=20_000)
    inc.save(0, s)
    s2 = jax.tree_util.tree_map(
        lambda x: x + np.float32(1e-4) if x.dtype == np.float32 else x, s)
    inc.save(1, s2)
    assert inc.bytes_written_delta < 0.5 * inc.bytes_written_full


def test_multilevel_coverage(tmp_path):
    ml = MultiLevelCheckpointer(
        local_store=CheckpointStore(str(tmp_path / "local"), num_shards=1),
        remote_store=CheckpointStore(str(tmp_path / "remote"), num_shards=1),
        local_every=2, remote_every=4)
    s = _state(5)
    for i in range(5):
        si = jax.tree_util.tree_map(
            lambda x: x + np.float32(i) if x.dtype == np.float32 else x, s)
        ml.save(i, si)
    # task failure: memory level has the newest (step 4)
    _, step, level = ml.restore(s, "task")
    assert (step, level) == (4, "memory")
    # node failure: memory lost, local has step 4 (saved at i=4, 4%2==0)
    ml.on_node_failure()
    _, step, level = ml.restore(s, "node")
    assert level == "local" and step == 4
    # cluster failure: only remote survives (step 4: 4%4==0)
    _, step, level = ml.restore(s, "cluster")
    assert level == "remote" and step == 4


def test_policy_hot_swap():
    p = CheckpointPolicy(60.0)
    p.reset(0.0)
    assert not p.due(30.0)
    assert p.due(61.0)
    p.set_interval(10.0, t=61.0)
    p.mark(61.0)
    assert p.due(71.5)
    assert p.history[-1] == (61.0, 10.0)
