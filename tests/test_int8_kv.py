"""int8 KV-cache decode path (beyond-paper decode lever)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ShapeConfig
from repro.configs import get_smoke_config
from repro.models import zoo


@pytest.mark.parametrize("arch", ["yi-6b", "recurrentgemma-2b"])
def test_int8_kv_decode_close_to_bf16(arch):
    B, S = 2, 16
    cfg16 = get_smoke_config(arch)
    cfg8 = dataclasses.replace(cfg16, kv_cache_dtype="int8")
    rng = jax.random.PRNGKey(0)
    params = zoo.init_params(cfg16, rng)
    tokens = jax.random.randint(rng, (B, S), 0, cfg16.vocab_size)

    def decode_all(cfg):
        decode = jax.jit(zoo.make_decode_step(cfg))
        caches = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            zoo.cache_specs(cfg, ShapeConfig("d", "decode", S, B)))
        toks = []
        c = caches
        for t in range(S):
            tok, c = decode(params, c,
                            {"tokens": tokens[:, t:t + 1],
                             "pos": jnp.full((B,), t, jnp.int32)})
            toks.append(np.asarray(tok))
        return np.concatenate(toks, axis=1)

    t16 = decode_all(cfg16)
    t8 = decode_all(cfg8)
    # greedy argmax tokens should almost always agree at this scale
    assert np.mean(t16 == t8) > 0.85


def test_int8_cache_specs_dtype():
    cfg = dataclasses.replace(get_smoke_config("yi-6b"), kv_cache_dtype="int8")
    specs = zoo.cache_specs(cfg, ShapeConfig("d", "decode", 32, 2))
    assert specs["k"].dtype == jnp.int8
    assert specs["v"].dtype == jnp.int8
