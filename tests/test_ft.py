"""Fault-tolerance layer: detector, injector, elastic rescale, stragglers."""
import numpy as np
import pytest

from repro.config import MeshConfig
from repro.ft import (FailureInjector, FailureModel, HeartbeatDetector,
                      StragglerDetector, plan_rescale)


def test_heartbeat_detector():
    det = HeartbeatDetector(num_hosts=4, timeout_s=10.0)
    det.heartbeat_all(0.0)
    det.heartbeat(0, 20.0)
    det.heartbeat(1, 20.0)
    assert det.failed_hosts(25.0) == [2, 3]
    assert not det.healthy(25.0)


def test_failure_model_mtbf_statistics():
    fm = FailureModel(mtbf_node_s=86400.0, num_nodes=64, seed=1)
    gaps = []
    t = 0.0
    for _ in range(300):
        nt = fm.next_failure_after(t)
        gaps.append(nt - t)
        t = nt
    assert abs(np.mean(gaps) - 86400.0 / 64) / (86400.0 / 64) < 0.2


def test_failure_model_weibull():
    fm = FailureModel(mtbf_node_s=86400.0, num_nodes=64,
                      distribution="weibull", weibull_shape=0.7, seed=2)
    gaps = [fm.next_failure_after(0.0) for _ in range(500)]
    assert abs(np.mean(gaps) - 86400.0 / 64) / (86400.0 / 64) < 0.25


def test_worst_case_injection_lands_before_ckpt_completion():
    inj = FailureInjector(epsilon_s=1.0)
    # interval 60s, ckpt cost 5s, last ckpt at t=0: completions at 65, 125, ...
    t = inj.worst_case_time(100.0, last_ckpt_t=0.0, interval_s=60.0,
                            ckpt_cost_s=5.0)
    assert abs(t - 124.0) < 1e-9      # 120 + 5 - 1


def test_rescale_keeps_tp_and_divides_batch():
    mesh = MeshConfig(multi_pod=False, data=16, model=16)
    plan = plan_rescale(mesh, hosts_alive=60, chips_per_host=4,
                        global_batch=256)
    assert plan.new.model == 16
    assert plan.new.data <= 15
    assert 256 % plan.new.data == 0
    assert plan.batch_ok


def test_rescale_multi_pod_degrades_to_single():
    mesh = MeshConfig(multi_pod=True, data=16, model=16, pods=2)
    plan = plan_rescale(mesh, hosts_alive=65, chips_per_host=4,
                        global_batch=256)   # 260 chips: can't fill 2 pods evenly
    assert plan.new.num_devices <= 260
    assert plan.new.model == 16


def test_rescale_raises_below_tp():
    mesh = MeshConfig(data=16, model=16)
    with pytest.raises(ValueError):
        plan_rescale(mesh, hosts_alive=3, chips_per_host=4)


def test_straggler_detector_flags_persistent_slow_host():
    det = StragglerDetector(num_hosts=4, slow_factor=1.4, patience=4)
    rng = np.random.default_rng(0)
    flagged = []
    for t in range(40):
        times = {h: 1.0 + rng.normal(0, 0.02) for h in range(4)}
        if t >= 10:
            times[2] = 2.5        # host 2 degrades
        flagged += det.observe_step(float(t), times)
    assert flagged == [2]
    assert det.flagged == {2}


def test_straggler_detector_ignores_transient_blips():
    det = StragglerDetector(num_hosts=4, patience=5)
    rng = np.random.default_rng(1)
    for t in range(40):
        times = {h: 1.0 + rng.normal(0, 0.02) for h in range(4)}
        if t in (10, 20):
            times[1] = 3.0        # isolated blips
        det.observe_step(float(t), times)
    assert not det.flagged
