"""Fault-tolerance layer: detector, injector, elastic rescale, stragglers."""
import numpy as np
import pytest

from repro.config import MeshConfig
from repro.ft import (Degradation, FailureInjector, FailureModel,
                      HeartbeatDetector, InjectedFailure, StragglerDetector,
                      plan_recovery, plan_rescale)


def test_heartbeat_detector():
    det = HeartbeatDetector(num_hosts=4, timeout_s=10.0)
    det.heartbeat_all(0.0)
    det.heartbeat(0, 20.0)
    det.heartbeat(1, 20.0)
    assert det.failed_hosts(25.0) == [2, 3]
    assert not det.healthy(25.0)


def test_failure_model_mtbf_statistics():
    fm = FailureModel(mtbf_node_s=86400.0, num_nodes=64, seed=1)
    gaps = []
    t = 0.0
    for _ in range(300):
        nt = fm.next_failure_after(t)
        gaps.append(nt - t)
        t = nt
    assert abs(np.mean(gaps) - 86400.0 / 64) / (86400.0 / 64) < 0.2


def test_failure_vocabulary_is_closed():
    # the KINDS set is validated everywhere, mirroring Decision.KINDS:
    # typos die at construction, not deep inside a campaign
    with pytest.raises(ValueError, match="unknown failure kind"):
        FailureModel(kinds=(("gremlin", 1.0),))
    with pytest.raises(ValueError, match="unknown crash kind"):
        InjectedFailure(kind="net_delay")     # degradations aren't raised
    with pytest.raises(ValueError, match="unknown crash kind"):
        FailureInjector().worst_case_failure(100.0, 0.0, 60.0, 5.0,
                                             kind="straggler")
    with pytest.raises(ValueError, match="unknown degradation kind"):
        Degradation(0.0, "node", 60.0)        # crashes aren't windows
    with pytest.raises(ValueError, match="unknown direction"):
        Degradation(0.0, "net_delay", 60.0, direction="sideways")
    with pytest.raises(ValueError, match="duration_s > 0"):
        Degradation(0.0, "backpressure", 0.0)


def test_failure_model_weibull():
    fm = FailureModel(mtbf_node_s=86400.0, num_nodes=64,
                      distribution="weibull", weibull_shape=0.7, seed=2)
    gaps = [fm.next_failure_after(0.0) for _ in range(500)]
    assert abs(np.mean(gaps) - 86400.0 / 64) / (86400.0 / 64) < 0.25


def test_worst_case_injection_lands_before_ckpt_completion():
    inj = FailureInjector(epsilon_s=1.0)
    # interval 60s, ckpt cost 5s, last ckpt at t=0: completions at 65, 125, ...
    t = inj.worst_case_time(100.0, last_ckpt_t=0.0, interval_s=60.0,
                            ckpt_cost_s=5.0)
    assert abs(t - 124.0) < 1e-9      # 120 + 5 - 1


def test_rescale_keeps_tp_and_divides_batch():
    mesh = MeshConfig(multi_pod=False, data=16, model=16)
    plan = plan_rescale(mesh, hosts_alive=60, chips_per_host=4,
                        global_batch=256)
    assert plan.new.model == 16
    assert plan.new.data <= 15
    assert 256 % plan.new.data == 0
    assert plan.batch_ok


def test_rescale_multi_pod_degrades_to_single():
    mesh = MeshConfig(multi_pod=True, data=16, model=16, pods=2)
    plan = plan_rescale(mesh, hosts_alive=65, chips_per_host=4,
                        global_batch=256)   # 260 chips: can't fill 2 pods evenly
    assert plan.new.num_devices <= 260
    assert plan.new.model == 16


def test_rescale_raises_below_tp():
    mesh = MeshConfig(data=16, model=16)
    with pytest.raises(ValueError):
        plan_rescale(mesh, hosts_alive=3, chips_per_host=4)


def test_rescale_batch_walkdown_terminates_at_data_one():
    # a prime global batch divides nothing: the divisibility walk-down
    # must terminate at data=1 (where any batch divides) instead of
    # looping or going to zero
    mesh = MeshConfig(data=16, model=16)
    plan = plan_rescale(mesh, hosts_alive=60, chips_per_host=4,
                        global_batch=977)
    assert plan.new.data == 1
    assert plan.new.model == 16
    assert plan.batch_ok          # 977 % 1 == 0: data=1 always shards


def test_rescale_multi_pod_symmetry_demotion_threshold():
    mesh = MeshConfig(multi_pod=True, data=16, model=16, pods=2)
    # below 2*model chips the pods cannot stay symmetric: single pod
    demoted = plan_rescale(mesh, hosts_alive=5, chips_per_host=4)   # 20 chips
    assert not demoted.new.multi_pod and demoted.new.model == 16
    # exactly 2*model chips is the smallest symmetric multi-pod mesh
    kept = plan_rescale(mesh, hosts_alive=8, chips_per_host=4)      # 32 chips
    assert kept.new.multi_pod and kept.new.data == 1


def test_recovery_standby_path_keeps_mesh():
    mesh = MeshConfig(data=16, model=16)
    rec = plan_recovery(mesh, hosts_lost=2, standbys=4)
    assert rec.mesh == mesh and not rec.rescaled and rec.rescale is None
    assert rec.standbys_used == 2 and rec.standbys_left == 2


def test_recovery_exhausted_standbys_rescales_down():
    mesh = MeshConfig(data=16, model=16)     # 256 chips = 64 hosts
    rec = plan_recovery(mesh, hosts_lost=5, standbys=1, chips_per_host=4,
                        global_batch=256)
    assert rec.rescaled and rec.standbys_left == 0
    assert rec.mesh.num_devices < mesh.num_devices
    assert rec.mesh.model == 16              # TP pinned through recovery
    assert 256 % rec.mesh.data == 0          # batch still shards cleanly
    assert rec.rescale.hosts_alive == 60     # 64 in-mesh + 1 standby - 5 lost

    with pytest.raises(ValueError):
        plan_recovery(mesh, hosts_lost=-1, standbys=0)


def test_worst_case_failure_is_host_targeted():
    inj = FailureInjector(epsilon_s=1.0)
    f = inj.worst_case_failure(100.0, last_ckpt_t=0.0, interval_s=60.0,
                               ckpt_cost_s=5.0, kind="node", host=3)
    assert abs(f.t - 124.0) < 1e-9            # same §III-C worst-case time
    assert f.kind == "node" and f.host == 3
    assert "host 3" in str(f)
    assert inj.log[-1]["host"] == 3 and inj.log[-1]["kind"] == "node"


def test_peer_loss_kills_host_then_its_ring_peer():
    inj = FailureInjector(epsilon_s=1.0)
    failures = inj.peer_loss(100.0, last_ckpt_t=0.0, interval_s=60.0,
                             ckpt_cost_s=5.0, host=3, num_hosts=4)
    assert [f.host for f in failures] == [3, 0]   # ring peer of 3 is 0
    assert all(f.kind == "node" for f in failures)
    # the second kill lands inside the window, before any new checkpoint
    # could complete
    assert failures[0].t < failures[1].t <= failures[0].t + 5.0
    assert inj.log[-1]["scenario"] == "peer_loss"
    # degenerate ring: a single host has no peer to lose
    solo = FailureInjector().peer_loss(0.0, 0.0, 60.0, 1.0,
                                       host=0, num_hosts=1)
    assert len(solo) == 1


def test_straggler_detector_flags_persistent_slow_host():
    det = StragglerDetector(num_hosts=4, slow_factor=1.4, patience=4)
    rng = np.random.default_rng(0)
    flagged = []
    for t in range(40):
        times = {h: 1.0 + rng.normal(0, 0.02) for h in range(4)}
        if t >= 10:
            times[2] = 2.5        # host 2 degrades
        flagged += det.observe_step(float(t), times)
    assert flagged == [2]
    assert det.flagged == {2}


def test_straggler_detector_two_host_true_median():
    # even host counts need the TRUE median (mean of the middle pair): the
    # upper-middle element of a 2-host cluster IS the slow host, so the
    # old comparison (st > factor * upper) could never flag it — 2.5 vs a
    # 3.5 threshold.  Against the true median 1.75 the threshold is 2.45
    # and the straggler is caught.
    det = StragglerDetector(num_hosts=2, slow_factor=1.4, patience=3)
    for t in range(10):
        det.observe_step(float(t), {0: 1.0, 1: 2.5})
    assert det.flagged == {1}


def test_straggler_detector_ignores_transient_blips():
    det = StragglerDetector(num_hosts=4, patience=5)
    rng = np.random.default_rng(1)
    for t in range(40):
        times = {h: 1.0 + rng.normal(0, 0.02) for h in range(4)}
        if t in (10, 20):
            times[1] = 3.0        # isolated blips
        det.observe_step(float(t), times)
    assert not det.flagged
