"""End-to-end behaviour: the full three-phase Khaos pipeline against the
simulator, and Khaos vs static baselines on a compressed workload — the
miniature of the paper's evaluation (benchmarks/ runs the full-size one)."""
import numpy as np
import pytest

from repro.config import KhaosConfig
from repro.core import (KhaosController, QoSModel, run_profiling,
                        select_failure_points, young_daly_interval)
from repro.data.stream import diurnal_rate, record_workload
from repro.ft.failures import FailureInjector
from repro.sim import (SimCostModel, SimDeployment, SimJobHandle,
                       StreamSimulator)


@pytest.fixture(scope="module")
def pipeline():
    sched = diurnal_rate(base=2000, amplitude=0.5, period=7200, seed=11)
    rec = record_workload(sched, duration=7200, seed=11)
    cost = SimCostModel(capacity_eps=3600.0, ckpt_duration_s=2.0)
    ss = select_failure_points(rec, m=4, smoothing_window=30)
    prof = run_profiling(lambda ci: SimDeployment(ci, rec, cost, warmup_s=200),
                         ss, [15, 45, 90, 180], margin=60)
    ci_f, tr_f, L_f, R_f = prof.flat()
    m_l = QoSModel().fit(ci_f, tr_f, L_f)
    m_r = QoSModel().fit(ci_f, tr_f, R_f)
    return sched, rec, cost, ss, prof, m_l, m_r


def test_phase1_phase2_produce_full_grids(pipeline):
    _, _, _, ss, prof, _, _ = pipeline
    assert prof.latencies.shape == (4, 4)       # m x z
    assert prof.recoveries.shape == (4, 4)
    assert np.all(prof.recoveries > 0)
    assert np.all(np.isfinite(prof.latencies))


def test_phase3_models_in_paper_error_band(pipeline):
    _, _, _, _, prof, m_l, m_r = pipeline
    ci_f, tr_f, L_f, R_f = prof.flat()
    # paper reports 9-12% (latency) and 7-13% (recovery) avg percent error;
    # in-sample fit must be at least that good
    assert m_l.avg_percent_error(ci_f, tr_f, L_f) < 0.15
    assert m_r.avg_percent_error(ci_f, tr_f, R_f) < 0.30


def test_khaos_beats_worst_static_on_recovery_violations(pipeline):
    sched, rec, cost, ss, prof, m_l, m_r = pipeline
    kcfg = KhaosConfig(latency_constraint=1.0, recovery_constraint=400.0,
                       optimization_period=60.0, ci_min=15, ci_max=180,
                       reconfig_cooldown=120.0)
    fail_times = [2400.0, 4800.0]

    def evaluate(ci_static=None):
        sim = StreamSimulator(cost, ci_s=ci_static or 60.0, schedule=sched)
        job = SimJobHandle(sim)
        ctl = None
        if ci_static is None:
            ctl = KhaosController(cfg=kcfg, m_l=m_l, m_r=m_r)
        inj = FailureInjector()
        for ft in fail_times:
            t = inj.worst_case_time(ft, 0.0, sim.policy.interval_s,
                                    cost.ckpt_duration_s)
            sim.inject_failure(t)
        while sim.t < 7200:
            sim.tick()
            if ctl is not None:
                ctl.maybe_optimize(job)
        recs = [r["recovery_s"] for r in sim.recoveries]
        viol = sum(max(0.0, r - kcfg.recovery_constraint) for r in recs)
        return viol, recs

    viol_khaos, recs_khaos = evaluate(None)
    viol_180, _ = evaluate(180.0)
    assert len(recs_khaos) == 2
    # Khaos must not be worse than the most violating static config
    assert viol_khaos <= viol_180 + 1e-9


def test_young_daly_baseline_in_range(pipeline):
    _, _, cost, _, _, _, _ = pipeline
    w = young_daly_interval(cost.ckpt_duration_s, mtbf_s=3600.0)
    assert 60 < w < 240
