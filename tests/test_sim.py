"""Discrete-event simulator invariants + Khaos-on-sim integration."""
import numpy as np
import pytest

from repro.config import KhaosConfig
from repro.core import (KhaosController, QoSModel, run_profiling,
                        select_failure_points)
from repro.data.stream import constant_rate, diurnal_rate, record_workload
from repro.sim import (SimCostModel, SimDeployment, SimJobHandle,
                       StreamSimulator, costmodel_from_arch)


def test_conservation_no_failures():
    cost = SimCostModel(capacity_eps=2000.0, ckpt_duration_s=1.0)
    sim = StreamSimulator(cost, ci_s=60.0, schedule=constant_rate(1000.0))
    sim.run_until(600.0)
    assert abs(sim.produced - (sim.consumed + sim.lag)) < 2 * 1000.0
    assert sim.ckpt_count >= 8
    lat = np.array(sim.metrics.series("latency").values)
    assert np.all(lat >= cost.base_latency_s - 1e-9)


def test_failure_rolls_back_to_last_checkpoint_and_recovers():
    cost = SimCostModel(capacity_eps=3000.0, ckpt_duration_s=1.0)
    sim = StreamSimulator(cost, ci_s=30.0, schedule=constant_rate(1500.0))
    sim.inject_failure(300.0)
    sim.run_until(2000.0)
    assert len(sim.recoveries) == 1
    r = sim.recoveries[0]
    # downtime + catch-up at rho=0.5: recovery should exceed plain downtime
    assert r["recovery_s"] > cost.downtime_s()
    # job caught up: lag near zero at the end
    assert sim.lag < 2 * 1500.0


def test_recovery_grows_with_ci_at_fixed_load():
    """The paper's core premise: longer CI -> more lost work -> longer
    recovery (rows of Table II/III) — under WORST-CASE injection (just
    before the next checkpoint completes, §III-C)."""
    from repro.ft.failures import FailureInjector
    cost = SimCostModel(capacity_eps=2600.0, ckpt_duration_s=1.5)
    recs = []
    for ci in (30.0, 240.0):
        sim = StreamSimulator(cost, ci_s=ci, schedule=constant_rate(2000.0))
        t = FailureInjector().worst_case_time(ci * 3 + 5.0, 0.0, ci,
                                              cost.ckpt_duration_s)
        sim.inject_failure(t)
        sim.run_until(t + 7000.0)
        assert sim.recoveries
        recs.append(sim.recoveries[0]["recovery_s"])
    assert recs[1] > recs[0]


def test_sync_checkpoint_reduces_capacity_and_raises_latency():
    lo = SimCostModel(capacity_eps=2200.0, ckpt_duration_s=3.0)
    lats = {}
    for ci in (10.0, 120.0):
        sim = StreamSimulator(lo, ci_s=ci, schedule=constant_rate(2000.0))
        sim.run_until(1200.0)
        lats[ci] = np.mean(sim.metrics.series("latency").values)
    assert lats[10.0] > lats[120.0]     # frequent ckpt -> higher latency


def test_flink_semantics_reconfigure_no_rollback():
    cost = SimCostModel(capacity_eps=2000.0, ckpt_duration_s=1.0)
    sim = StreamSimulator(cost, ci_s=60.0, schedule=constant_rate(1000.0),
                          flink_semantics=True)
    sim.run_until(200.0)
    consumed_before = sim.consumed
    sim.set_ci(30.0)
    sim.run_until(400.0)
    # savepoint: no reprocessing (consumed never decreases)
    assert sim.consumed >= consumed_before
    assert sim.policy.interval_s == 30.0
    # but the restart downtime produced lag that was then drained
    assert len(sim.metrics.series("latency")) > 0


def test_profiling_recovery_monotone_in_ci_on_average():
    sched = diurnal_rate(base=1500, amplitude=0.4, period=7200, seed=3)
    rec = record_workload(sched, duration=7200, seed=3)
    ss = select_failure_points(rec, m=3, smoothing_window=30)
    cost = SimCostModel(capacity_eps=2600.0, ckpt_duration_s=1.5)
    prof = run_profiling(lambda ci: SimDeployment(ci, rec, cost, warmup_s=200),
                         ss, [30, 240], margin=60)
    # mean over failure points: recovery at CI=240 > at CI=30
    assert prof.recoveries[:, 1].mean() > prof.recoveries[:, 0].mean()
    assert np.all(prof.latencies > 0)


def test_khaos_controller_on_sim_reconfigures_under_violation():
    """Integration: controller detects predicted recovery violations and
    moves the CI; the sim applies it with flink semantics."""
    rng = np.random.default_rng(0)
    ci = rng.uniform(10, 300, 120)
    tr = rng.uniform(800, 2200, 120)
    m_l = QoSModel().fit(ci, tr, 0.4 + 2.0 / ci)
    m_r = QoSModel().fit(ci, tr, 80 + 1.2 * ci + 0.02 * tr)

    cfg = KhaosConfig(latency_constraint=1.0, recovery_constraint=240.0,
                      optimization_period=30.0, ci_min=10, ci_max=300,
                      reconfig_cooldown=60.0)
    cost = SimCostModel(capacity_eps=2600.0, ckpt_duration_s=1.0)
    sim = StreamSimulator(cost, ci_s=290.0, schedule=constant_rate(1800.0))
    job = SimJobHandle(sim)
    ctl = KhaosController(cfg=cfg, m_l=m_l, m_r=m_r)
    while sim.t < 900.0:
        sim.tick()
        ctl.maybe_optimize(job)
    # predicted recovery at CI=290 ~ 80+348+36 >> 240 -> must reconfigure down
    assert job.reconfigurations, "controller never acted"
    t0, new_ci = job.reconfigurations[0]
    assert new_ci < 200.0
    err = ctl.error_analysis()
    assert "latency_avg_pct_error" in err


def test_costmodel_from_arch():
    cm = costmodel_from_arch(param_count=6_000_000_000, bound_step_s=2.0,
                             tokens_per_step=1_048_576, seq_len=4096,
                             n_hosts=64)
    assert cm.capacity_eps == pytest.approx(128.0, rel=0.01)
    assert cm.ckpt_duration_s > 0.5      # 72 GB over 64 GB/s aggregate
