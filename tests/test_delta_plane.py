"""Device-resident delta plane: on-device encode in front of D2H
(``pipeline.DeltaLeafSource``), placement/codec as plan dimensions, and
the batched controller evaluation that rides along in this PR.

All kernel work runs in Pallas interpret mode on the CPU backend
(``ckpt_delta.ops.default_interpret``), so every test here is tier-1.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, CheckpointPlan,
                              DeltaLeafSource, DeviceDeltaBase)
from repro.checkpoint.incremental import (apply_delta, read_delta_manifest,
                                          write_delta)
from repro.kernels.ckpt_delta.ref import encode_ref, lossless_encode_ref

jax.config.update("jax_platform_name", "cpu")


def _state(seed=0, n=3000):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((n,))
                                    .astype(np.float32)),
                   "frozen": jnp.asarray(rng.standard_normal((256,))
                                         .astype(np.float32))},
        "host": rng.standard_normal((128,)).astype(np.float32),
        "ids": np.arange(64, dtype=np.int64),
        "step": jnp.asarray(np.int32(seed)),
    }


def _bump(state, eps=np.float32(1e-4)):
    out = dict(state)
    out["params"] = {"w": state["params"]["w"] + eps,
                     "frozen": state["params"]["frozen"]}     # unchanged
    out["host"] = state["host"] + np.float32(0.5)
    return out


def _bit_exact(a, b) -> bool:
    la = [np.asarray(x) for x in jax.tree_util.tree_leaves(a)]
    lb = [np.asarray(x) for x in jax.tree_util.tree_leaves(b)]
    return all(np.array_equal(x, y) for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# DeltaLeafSource output == ref.py host oracle (kernel parity, tier-1)
# ---------------------------------------------------------------------------

def test_delta_leaf_source_matches_host_oracle_lossless():
    s0 = _state(0)
    s1 = _bump(s0)
    src = DeltaLeafSource(s1, DeviceDeltaBase(s0), codec="lossless")
    src.wait()
    d_ref, r_ref = lossless_encode_ref(np.asarray(s1["params"]["w"]),
                                       np.asarray(s0["params"]["w"]))
    enc = src.encoded("params/w")
    assert np.array_equal(enc[""], d_ref)
    assert enc[""].dtype == np.float32 and enc["::r"].dtype == np.uint32
    assert np.array_equal(enc["::r"], r_ref)
    # unchanged device leaf -> device-side zero marker
    assert src.encoded("params/frozen") == "zero"
    # host and non-f32 leaves fall back (None) and stay raw-readable
    assert src.encoded("host") is None
    assert src.encoded("ids") is None
    assert np.array_equal(src.get("host"), s1["host"])
    # encoded link accounting: w delta (resid all-zero => skipped) +
    # raw fallbacks; strictly under the raw state bytes
    raw = sum(np.asarray(l).nbytes for l in jax.tree_util.tree_leaves(s1))
    assert 0 < src.bytes_on_link() < raw


def test_delta_leaf_source_matches_host_oracle_int8():
    s0 = _state(1)
    s1 = _bump(s0, eps=np.float32(3e-3))
    src = DeltaLeafSource(s1, DeviceDeltaBase(s0), codec="int8")
    src.wait()
    delta = np.asarray(s1["params"]["w"]) - np.asarray(s0["params"]["w"])
    q_ref, s_ref = encode_ref(delta.reshape(-1))
    enc = src.encoded("params/w")
    assert np.array_equal(enc["::q"], q_ref)
    assert np.array_equal(enc["::s"], s_ref)
    # int8 payload is ~1.25 B/elem vs 4 B/elem raw for the encoded leaves
    w_bytes = np.asarray(s1["params"]["w"]).nbytes
    assert enc["::q"].nbytes + enc["::s"].nbytes < 0.5 * w_bytes


def test_delta_leaf_source_residual_transferred_when_nonzero():
    """Elements whose base and new values are far apart (ratio > 2) make
    base + delta round away from new — the residual must cross the link
    and restore must stay bit-exact."""
    base_w = np.array([1.0, 1e-8, -3.0, 1e20] * 256, np.float32)
    new_w = np.array([1.0 + 1e-7, 7.25, 3e-8, -1.5] * 256, np.float32)
    s0 = {"w": jnp.asarray(base_w)}
    s1 = {"w": jnp.asarray(new_w)}
    src = DeltaLeafSource(s1, DeviceDeltaBase(s0), codec="lossless")
    src.wait()
    d_ref, r_ref = lossless_encode_ref(new_w, base_w)
    assert r_ref.any(), "fixture must produce a nonzero residual"
    enc = src.encoded("w")
    assert np.array_equal(enc["::r"], r_ref)
    assert np.array_equal(enc[""], d_ref)


# ---------------------------------------------------------------------------
# int8 round trip obeys the documented group-quantization bound
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_within_group_bound(tmp_path):
    """|err| <= max|delta_group| / 254 per element (scale = amax/127,
    round-to-nearest) — the bound documented on ``int8_encode_leaf``."""
    from repro.kernels.ckpt_delta.ref import GROUP, decode_ref

    rng = np.random.default_rng(5)
    base = rng.standard_normal((4 * GROUP,)).astype(np.float32)
    new = (base + rng.uniform(-0.01, 0.01, base.shape)
           .astype(np.float32)).astype(np.float32)
    src = DeltaLeafSource({"w": jnp.asarray(new)},
                          DeviceDeltaBase({"w": jnp.asarray(base)}),
                          codec="int8")
    src.wait()
    enc = src.encoded("w")
    got = decode_ref(enc["::q"], enc["::s"])[:new.size]
    delta = new - base
    amax = np.abs(delta.reshape(-1, GROUP)).max(axis=1)
    bound = np.repeat(np.maximum(amax, 1e-12) / 254.0, GROUP)
    err = np.abs(got - delta)
    assert (err <= bound + 1e-9).all(), float((err - bound).max())


# ---------------------------------------------------------------------------
# cross-placement restore: blobs are byte-compatible both ways
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("save_placement,restore_placement",
                         [("device", "host"), ("host", "device")])
def test_cross_placement_restore_bit_exact(tmp_path, save_placement,
                                           restore_placement):
    plan_save = CheckpointPlan(mode="incremental", full_every=4,
                               encode_placement=save_placement)
    d = str(tmp_path / "ckpt")
    mgr = CheckpointManager(d, plan_save)
    s0, s1 = _state(0), _bump(_state(0))
    mgr.save(0, s0, 0.0)
    rep = mgr.save(1, s1, 1.0)
    assert rep.kind == "delta"
    meta = read_delta_manifest(os.path.join(d, "local"), 1)
    assert meta["placement"] == save_placement
    # restore through a manager configured for the OTHER placement
    mgr2 = CheckpointManager(d, CheckpointPlan(
        mode="incremental", full_every=4,
        encode_placement=restore_placement))
    got = mgr2.restore(_state(0), "node")
    assert got.step == 1 and got.kind == "full+delta"
    assert _bit_exact(got.state, s1)


@pytest.mark.parametrize("codec", ["lossless", "int8"])
def test_device_delta_blobs_byte_identical_to_host(tmp_path, codec):
    """Acceptance: a fixed-seed device-encoded delta produces the same
    blobs (and the same manifest, modulo the placement field) as the host
    encoder, and both restore identically."""
    s0, s1 = _state(3), _bump(_state(3), eps=np.float32(2e-3))
    dirs = {}
    for placement in ("host", "device"):
        d = str(tmp_path / placement)
        os.makedirs(d)
        if placement == "device":
            src = DeltaLeafSource(s1, DeviceDeltaBase(s0), codec=codec)
        else:
            src = jax.tree_util.tree_map(np.asarray, s1)
        base = jax.tree_util.tree_map(np.asarray, s0)
        write_delta(d, 1, src, base, 0, 1.0, mode=codec, codec="zlib")
        dirs[placement] = os.path.join(d, "delta_0000000001")
    host_files = sorted(os.listdir(dirs["host"]))
    assert sorted(os.listdir(dirs["device"])) == host_files
    for fname in host_files:
        with open(os.path.join(dirs["host"], fname), "rb") as f:
            h = f.read()
        with open(os.path.join(dirs["device"], fname), "rb") as f:
            dev = f.read()
        if fname == "delta_manifest.json":
            import json
            mh, md = json.loads(h), json.loads(dev)
            assert mh.pop("placement") == "host"
            assert md.pop("placement") == "device"
            assert mh == md
        else:
            assert h == dev, f"blob {fname} differs across placements"
    base_np = jax.tree_util.tree_map(np.asarray, s0)
    a = apply_delta(str(tmp_path / "host"), 1, base_np)
    b = apply_delta(str(tmp_path / "device"), 1, base_np,
                    placement="device")
    assert _bit_exact(a, b)
    if codec == "lossless":
        assert _bit_exact(a, s1)


# ---------------------------------------------------------------------------
# device base lifecycle: plan-switch carry-over, failure wipe, savepoint
# ---------------------------------------------------------------------------

def test_plan_switch_carries_device_base_over(tmp_path):
    plan = CheckpointPlan(mode="incremental", full_every=8,
                          encode_placement="device")
    mgr = CheckpointManager(str(tmp_path), plan)
    s0 = _state(0)
    mgr.savepoint(0, s0, 0.0)
    assert mgr._device_base is not None
    # the rebuild (set_plan semantics): a fresh manager adopting runtime
    # state must keep device-encoding deltas against the drained full
    mgr2 = CheckpointManager(str(tmp_path), CheckpointPlan(
        mode="incremental", full_every=8, encode_placement="device",
        interval_s=10.0))
    mgr2.adopt_runtime_state(mgr)
    # the drained device base rides the rebuild (no re-upload)
    assert mgr2._device_base is mgr._device_base
    s1 = _bump(s0)
    rep = mgr2.save(1, s1, 1.0)      # trigger 0 of the new cadence: full
    assert rep.kind == "full"
    s2 = _bump(s1)
    rep = mgr2.save(2, s2, 2.0)
    assert rep.kind == "delta"
    meta = read_delta_manifest(str(tmp_path / "local"), 2)
    assert meta["placement"] == "device"
    got = mgr2.restore(_state(0), "node")
    assert got.step == 2 and _bit_exact(got.state, s2)
    # a node failure wipes the device base with the rest of runtime state
    mgr2.on_failure("node")
    assert mgr2._device_base is None
    rep2 = mgr2.save(3, s2, 3.0)
    assert rep2.kind == "full"          # chain restarts


def test_save_report_bytes_on_link_distinguishes_link_from_disk(tmp_path):
    """Satellite: bytes_on_link (pre-compression, post-encode) vs
    bytes_written (post-compression).  Host deltas move the raw state;
    device int8 deltas move ~0.3x of it."""
    s0 = _state(0, n=8192)
    s1 = _bump(s0)
    raw = sum(np.asarray(l).nbytes for l in jax.tree_util.tree_leaves(s0))
    host = CheckpointManager(str(tmp_path / "h"), CheckpointPlan(
        mode="incremental", full_every=4))
    host.save(0, s0, 0.0)
    rep = host.save(1, s1, 1.0)
    assert rep.kind == "delta" and rep.bytes_on_link == raw
    dev = CheckpointManager(str(tmp_path / "d"), CheckpointPlan(
        mode="incremental", full_every=4, encode_placement="device",
        delta_codec="int8"))
    full_rep = dev.save(0, s0, 0.0)
    assert full_rep.bytes_on_link == raw      # fulls always move the state
    drep = dev.save(1, s1, 1.0)
    assert drep.kind == "delta"
    assert 0 < drep.bytes_on_link < 0.5 * raw
    st = dev.stats()
    assert st["bytes_on_link"] == full_rep.bytes_on_link + drep.bytes_on_link
    # a device delta trigger that ALSO takes a remote full pulls the raw
    # state for that write — the raw D2H must be accounted, not just the
    # encoded payload
    ml = CheckpointManager(str(tmp_path / "ml"), CheckpointPlan(
        mode="incremental", full_every=4, levels=("local", "remote"),
        remote_every=2, encode_placement="device", delta_codec="int8"))
    ml.save(0, s0, 0.0)                       # full everywhere
    ml.save(1, _bump(s0), 1.0)                # delta, local only
    rep2 = ml.save(2, _bump(_bump(s0)), 2.0)  # delta local + remote FULL
    assert rep2.kind == "delta" and "remote" in rep2.levels
    assert rep2.bytes_on_link > raw           # payload + raw full pull
    # legacy incremental checkpointer reports the link quantity too
    from repro.checkpoint import CheckpointStore, IncrementalCheckpointer
    inc = IncrementalCheckpointer(CheckpointStore(str(tmp_path / "l"),
                                                  num_shards=2))
    inc.save(0, jax.tree_util.tree_map(np.asarray, s0))
    assert inc.stats()["bytes_on_link"] == raw


# ---------------------------------------------------------------------------
# cost model: placement pricing, v2 calibration, coverage assertions
# ---------------------------------------------------------------------------

def _v2_calibration():
    return {
        "schema": "bench_ckpt/2",
        "state_bytes": 32 * 2**20,
        "full_write_s": 2.0,
        "restore_s": 1.5,
        "delta_fraction": 0.05,
        "delta_int8_fraction": 0.01,
        "delta_encode_s_per_byte": 3.0 / (32 * 2**20),
        "device": {
            "lossless": {"bytes_on_link": 33 * 2**20 // 32,
                         "link_fraction": 1.01, "encode_s": 0.02},
            "int8": {"bytes_on_link": 8 * 2**20,
                     "link_fraction": 0.25, "encode_s": 0.01},
        },
        "plans": {"incr8-sync": {"bytes_per_trigger": 1.0, "write_s": 0.1,
                                 "blocking_s": 0.1, "encode_cpu_s": 0.5}},
    }


def test_from_calibration_v2_prices_device_placement():
    from repro.sim import SimCostModel

    cost = SimCostModel.from_calibration(_v2_calibration())
    assert cost.device_link_fraction_int8 == 0.25
    assert cost.device_encode_s == 0.02
    # device delta drops the per-trigger host encode (3 s) for the
    # measured device encode (0.01-0.02 s)
    host_d = cost.write_duration("delta", encoding="int8")
    dev_d = cost.write_duration("delta", encoding="int8",
                                placement="device")
    assert dev_d < host_d
    assert np.isclose(host_d - dev_d, 3.0 - 0.01)
    # plan-level: the device-int8 plan has the cheapest trigger average
    incr = CheckpointPlan(mode="incremental", full_every=8)
    dev8 = CheckpointPlan(mode="incremental", full_every=8,
                          encode_placement="device", delta_codec="int8")
    assert cost.avg_write_duration(dev8) < cost.avg_write_duration(incr)
    # link-bytes accounting: host plans move the raw state every trigger;
    # the device-int8 plan averages fulls at 1.0x with deltas at 0.25x
    assert cost.avg_link_bytes(incr) == cost.state_bytes
    want = (cost.state_bytes + 7 * 0.25 * cost.state_bytes) / 8
    assert np.isclose(cost.avg_link_bytes(dev8), want)
    # a delta trigger that also takes a remote full pays payload + raw
    dev_ml = CheckpointPlan(mode="incremental", full_every=8,
                            levels=("local", "remote"), remote_every=4,
                            encode_placement="device", delta_codec="int8")
    assert np.isclose(cost.trigger_link_bytes(dev_ml, 4),
                      1.25 * cost.state_bytes)


def test_from_calibration_v1_fallback_and_v2_rejects_bad_device():
    from repro.sim import SimCostModel

    v1 = {k: v for k, v in _v2_calibration().items() if k != "device"}
    v1["schema"] = "bench_ckpt/1"
    cost = SimCostModel.from_calibration(v1)
    assert cost.device_link_fraction_int8 == \
        SimCostModel.device_link_fraction_int8   # modeled default
    bad = _v2_calibration()
    del bad["device"]["int8"]["encode_s"]
    with pytest.raises(ValueError, match="device"):
        SimCostModel.from_calibration(bad)
    bad2 = _v2_calibration()
    del bad2["device"]
    with pytest.raises(ValueError, match="device"):
        SimCostModel.from_calibration(bad2)


def test_surviving_levels_rejects_unknown_failure_kind():
    from repro.checkpoint.multilevel import allowed_levels
    from repro.sim import SimCostModel

    cost = SimCostModel()
    plan = CheckpointPlan(levels=("memory", "local", "remote"))
    assert cost.surviving_levels(plan, "node") == ("local", "remote")
    with pytest.raises(ValueError, match="unknown failure kind"):
        cost.surviving_levels(plan, "rack")
    with pytest.raises(ValueError, match="known kinds"):
        allowed_levels("typo")


# ---------------------------------------------------------------------------
# optimizer: (placement x codec) variants, campaign-verified
# ---------------------------------------------------------------------------

def test_optimize_plan_surfaces_campaign_verified_device_int8():
    """Acceptance: with a calibrated cost model, the default variant grid
    contains (placement=device, codec=int8) candidates and the campaign
    verifier scores at least one of them end-to-end."""
    from repro.core import QoSModel, optimize_plan
    from repro.core.ci_optimizer import default_plan_variants
    from repro.data.stream import constant_rate
    from repro.sim import SimCostModel
    from repro.sim.batched import make_plan_verifier

    cost = SimCostModel.from_calibration(
        _v2_calibration(), capacity_eps=4600.0, ckpt_sync_penalty=0.6)
    variants = default_plan_variants(cost, ci_ref=60.0)
    dev_int8 = [p for p in variants if p.encode_placement == "device"
                and p.delta_codec == "int8"]
    assert dev_int8, "variant grid lost the device-int8 dimension"
    rng = np.random.default_rng(0)
    ci = rng.uniform(10, 120, 200)
    tr = rng.uniform(1000, 4000, 200)
    m_l = QoSModel().fit(ci, tr, cost.base_latency_s + 40.0 / ci + tr * 1e-5)
    m_r = QoSModel().fit(ci, tr, 80.0 + 1.2 * ci + 0.01 * tr)
    verifier = make_plan_verifier(cost, schedule=constant_rate(2500.0),
                                  max_recovery_s=900.0)
    res = optimize_plan(m_l, m_r, tr_avg=2500.0, l_const=1.0, r_const=240.0,
                        p=1.0, ci_min=10, ci_max=120, cost=cost,
                        verifier=verifier, verify_top_k=4)
    assert res.feasible and res.verified
    scored = [c for c in res.candidates
              if c.plan.encode_placement == "device"
              and c.plan.delta_codec == "int8" and c.sim is not None]
    assert scored, "no device-int8 candidate was campaign-verified"
    assert {"latency_s", "recovery_s"} <= set(scored[0].sim)


# ---------------------------------------------------------------------------
# drive_campaign: shared QoS evaluation, Decisions bit-identical
# ---------------------------------------------------------------------------

def test_drive_campaign_batched_predictions_bit_identical_decisions():
    """Satellite: the per-period QoS-model reads are batched (one
    ``QoSModel.predict`` over all lanes), and the per-lane Decisions are
    BIT-identical to the per-lane evaluation loop."""
    from repro.config import KhaosConfig
    from repro.core import KhaosRuntime
    from repro.data.stream import constant_rate, dense_rates
    from repro.sim import BatchedCampaign, LaneSpec, SimCostModel
    from repro.sim.batched import BatchedLaneHandle

    cost = SimCostModel(capacity_eps=2600.0, ckpt_duration_s=1.0)
    kcfg = KhaosConfig(latency_constraint=1.2, recovery_constraint=240.0,
                       optimization_period=30.0, ci_min=10, ci_max=120,
                       reconfig_cooldown=60.0)
    sched = constant_rate(1800.0)

    def make_campaign():
        lanes = [LaneSpec(rates=dense_rates(0.0, 400, schedule=sched),
                          ci_s=float(ci),
                          failures=((120.0, "node"),) if i % 2 else ())
                 for i, ci in enumerate((15, 40, 80, 115))]
        return BatchedCampaign(cost, lanes)

    def fresh_runtime():
        rt = KhaosRuntime(kcfg, cost=cost)
        from repro.core.qos_models import QoSModel
        rng = np.random.default_rng(0)
        ci = rng.uniform(10, 120, 150)
        tr = rng.uniform(1000, 2400, 150)
        m_l = QoSModel().fit(ci, tr, cost.base_latency_s + 30.0 / ci)
        m_r = QoSModel().fit(ci, tr, 60.0 + 1.1 * ci + 0.02 * tr)
        rt.install_models(m_l, m_r)
        return rt

    # batched path: drive_campaign (shared predictions)
    rt = fresh_runtime()
    sup = rt.drive_campaign(make_campaign())

    # oracle: the pre-batching per-lane loop, scalar predict per lane
    rt2 = fresh_runtime()
    camp = make_campaign()
    handles = [BatchedLaneHandle(camp, i) for i in range(camp.n_lanes)]
    controllers = [rt2._make_controller() for _ in handles]
    period = max(1, int(round(kcfg.optimization_period)))
    while not camp.done:
        camp.run(n_ticks=period)
        for ctl, h in zip(controllers, handles):
            if h.alive():
                ctl.maybe_optimize(h)
    for ctl, h in zip(controllers, handles):
        ctl.maybe_optimize(h)

    for lane, (ctl, got) in enumerate(zip(controllers, sup.controllers)):
        want = ctl.decisions
        have = got.decisions
        assert len(want) == len(have), (lane, len(want), len(have))
        for dw, dh in zip(want, have):
            assert (dw.t, dw.kind) == (dh.t, dh.kind), lane
            for f in ("latency", "tr_avg", "predicted_recovery", "new_ci"):
                a, b = getattr(dw, f), getattr(dh, f)
                assert (a is None and b is None) or \
                    np.array_equal(np.float64(a), np.float64(b),
                                   equal_nan=True), (lane, f, a, b)
            assert (dw.new_plan is None) == (dh.new_plan is None)
            if dw.new_plan is not None:
                assert dw.new_plan == dh.new_plan
