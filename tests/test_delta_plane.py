"""Device-resident delta plane: the f32 subtree packed into ONE
GROUP-aligned mega-buffer, ONE fused encode kernel in front of D2H
(``pipeline.DeltaLeafSource``), placement/codec as plan dimensions, and
the batched controller evaluation that rides along.

All kernel work runs in Pallas interpret mode on the CPU backend
(``ckpt_delta.ops.default_interpret``), so every test here is tier-1.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, CheckpointPlan,
                              DeltaLeafSource, DeviceDeltaBase, FlatLayout)
from repro.checkpoint.incremental import (apply_delta, read_delta_manifest,
                                          write_delta)
from repro.kernels.ckpt_delta.ref import (GROUP, decode_ref,
                                          flat_int8_encode_ref,
                                          flat_lossless_encode_ref,
                                          lossless_encode_ref, pack_flat_ref)
from repro.utils.trees import tree_flatten_with_names

jax.config.update("jax_platform_name", "cpu")


def _state(seed=0, n=3000):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((n,))
                                    .astype(np.float32)),
                   "frozen": jnp.asarray(rng.standard_normal((256,))
                                         .astype(np.float32))},
        "host": rng.standard_normal((128,)).astype(np.float32),
        "ids": np.arange(64, dtype=np.int64),
        "step": jnp.asarray(np.int32(seed)),
    }


def _bump(state, eps=np.float32(1e-4)):
    out = dict(state)
    out["params"] = {"w": state["params"]["w"] + eps,
                     "frozen": state["params"]["frozen"]}     # unchanged
    out["host"] = state["host"] + np.float32(0.5)
    return out


def _bit_exact(a, b) -> bool:
    la = [np.asarray(x) for x in jax.tree_util.tree_leaves(a)]
    lb = [np.asarray(x) for x in jax.tree_util.tree_leaves(b)]
    return all(np.array_equal(x, y) for x, y in zip(la, lb))


def _flat_ref(layout: FlatLayout, state) -> np.ndarray:
    """Host-oracle packed mega-buffer of ``state`` in layout order."""
    leaves = {n: np.asarray(l) for n, l in tree_flatten_with_names(state)}
    return pack_flat_ref([leaves[n] for n in layout.names])


# ---------------------------------------------------------------------------
# DeltaLeafSource flat payload == ref.py host oracle (kernel parity, tier-1)
# ---------------------------------------------------------------------------

def test_delta_leaf_source_matches_host_oracle_lossless():
    s0 = _state(0)
    s1 = _bump(s0)
    src = DeltaLeafSource(s1, DeviceDeltaBase(s0), codec="lossless")
    src.wait()
    layout = src.layout
    # only the f32 jax leaves pack into the mega-buffer; host and non-f32
    # leaves stay outside the layout and remain raw-readable
    assert sorted(layout.names) == ["params/frozen", "params/w"]
    for name in ("host", "ids", "step"):
        assert name not in layout.by_name
    d_ref, r_ref, changed_ref, _ = flat_lossless_encode_ref(
        _flat_ref(layout, s1), _flat_ref(layout, s0),
        layout.group_leaf, len(layout.names))
    payload = src.flat_payload()
    assert payload["d"].dtype == np.float32
    assert np.array_equal(payload["d"], d_ref)
    # the tiny bump keeps every element within 2x of its base, so the
    # residual plane is all-zero: its D2H is skipped, marker recorded
    assert not r_ref.any() and payload["r"] == "zero"
    # unchanged packed leaf -> fused change count 0 -> skip-zero marker
    assert src.zero_names == tuple(
        e.name for e, c in zip(layout.entries, changed_ref) if not c) \
        == ("params/frozen",)
    assert np.array_equal(src.get("host"), s1["host"])
    # link accounting, exactly: the d plane (residual skipped) + the
    # eager host-leaf copies; the lazy jax scalar "step" is not pulled
    assert src.bytes_on_link() == 4 * layout.total \
        + s1["host"].nbytes + s1["ids"].nbytes


def test_delta_leaf_source_matches_host_oracle_int8():
    s0 = _state(1)
    s1 = _bump(s0, eps=np.float32(3e-3))
    src = DeltaLeafSource(s1, DeviceDeltaBase(s0), codec="int8")
    src.wait()
    layout = src.layout
    q_ref, s_ref, _ = flat_int8_encode_ref(
        _flat_ref(layout, s1), _flat_ref(layout, s0),
        layout.group_leaf, len(layout.names))
    payload = src.flat_payload()
    assert np.array_equal(payload["q"], q_ref)
    assert np.array_equal(payload["s"], s_ref)
    # int8 payload is ~1.004 B/elem vs 4 B/elem f32 for the packed subtree
    assert payload["q"].nbytes + payload["s"].nbytes < 0.5 * 4 * layout.total


def test_delta_leaf_source_residual_transferred_when_nonzero():
    """Elements whose base and new values are far apart (ratio > 2) make
    base + delta round away from new — the residual must cross the link
    and restore must stay bit-exact."""
    base_w = np.array([1.0, 1e-8, -3.0, 1e20] * 256, np.float32)
    new_w = np.array([1.0 + 1e-7, 7.25, 3e-8, -1.5] * 256, np.float32)
    s0 = {"w": jnp.asarray(base_w)}
    s1 = {"w": jnp.asarray(new_w)}
    src = DeltaLeafSource(s1, DeviceDeltaBase(s0), codec="lossless")
    src.wait()
    d_ref, r_ref = lossless_encode_ref(new_w, base_w)
    assert r_ref.any(), "fixture must produce a nonzero residual"
    payload = src.flat_payload()
    assert np.array_equal(payload["r"], r_ref)
    assert np.array_equal(payload["d"], d_ref)


# ---------------------------------------------------------------------------
# fused flat kernels == host oracles on an awkward layout (tier-1)
# ---------------------------------------------------------------------------

def test_flat_ops_match_ref_on_awkward_leaf_sizes():
    """Parity of the fused pallas ops (interpret mode) with the numpy
    oracles on a layout mixing odd, tiny and exactly-one-group leaves —
    padded extents, the group->leaf scatter-add and the per-leaf change
    stats included."""
    from repro.kernels.ckpt_delta.ops import (flat_int8_encode,
                                              flat_lossless_encode,
                                              pack_flat)

    rng = np.random.default_rng(7)
    sizes = [3000, 256, 1, 5000, GROUP]
    base = [rng.standard_normal((s,)).astype(np.float32) for s in sizes]
    new = [b + rng.uniform(-1e-2, 1e-2, b.shape).astype(np.float32)
           for b in base]
    new[1] = base[1].copy()                      # one unchanged leaf
    layout = FlatLayout([(f"l{i}", (s,)) for i, s in enumerate(sizes)])
    nl = len(sizes)
    nf = pack_flat([jnp.asarray(x) for x in new])
    bf = pack_flat([jnp.asarray(x) for x in base])
    assert np.array_equal(np.asarray(nf), pack_flat_ref(new))
    gl = layout.group_leaf_device()
    d, r, lc, lz = flat_lossless_encode(nf, bf, gl, num_leaves=nl,
                                        interpret=True)
    d_ref, r_ref, lc_ref, lz_ref = flat_lossless_encode_ref(
        pack_flat_ref(new), pack_flat_ref(base), layout.group_leaf, nl)
    assert np.array_equal(np.asarray(d), d_ref)
    assert np.array_equal(np.asarray(r), r_ref)
    assert np.array_equal(np.asarray(lc), lc_ref)
    assert np.array_equal(np.asarray(lz), lz_ref)
    assert int(lc_ref[1]) == 0 and lc_ref[[0, 2, 3, 4]].all()
    q, s, lc2 = flat_int8_encode(nf, bf, gl, num_leaves=nl, interpret=True)
    q_ref, s_ref, _ = flat_int8_encode_ref(
        pack_flat_ref(new), pack_flat_ref(base), layout.group_leaf, nl)
    assert np.array_equal(np.asarray(q), q_ref)
    assert np.array_equal(np.asarray(s), s_ref)
    assert np.array_equal(np.asarray(lc2), lc_ref)


def test_flat_blocks_pads_to_block_multiple():
    """Compiled-mode block padding: an 11-group buffer at block_groups=4
    pads to 12 groups of zero-vs-zero diff scattered onto leaf 0 (adding
    nothing); interpret mode collapses to ONE whole-buffer block,
    unpadded — the per-grid-step cost structure documented on
    ``_flat_blocks``."""
    from repro.kernels.ckpt_delta.ops import _flat_blocks

    n = 11 * GROUP
    nf = jnp.ones((n,), jnp.float32)
    bf = jnp.zeros((n,), jnp.float32)
    gl = jnp.asarray(np.arange(11, dtype=np.int32) // 3)
    nf2, bf2, gl2, n2, bg = _flat_blocks(nf, bf, gl, 4, interpret=False)
    assert (n2, bg) == (n, 4) and nf2.shape[0] == 12 * GROUP
    assert not np.asarray(nf2[n:]).any() and not np.asarray(bf2[n:]).any()
    assert int(gl2[-1]) == 0
    nf3, _, gl3, n3, bg3 = _flat_blocks(nf, bf, gl, 4, interpret=True)
    assert (n3, bg3) == (n, 11) and nf3.shape[0] == n and gl3.shape[0] == 11


# ---------------------------------------------------------------------------
# int8 round trip obeys the documented group-quantization bound
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_within_group_bound():
    """|err| <= max|delta_group| / 254 per element (scale = amax/127,
    round-to-nearest) — the bound documented on the int8 encode ops."""
    rng = np.random.default_rng(5)
    base = rng.standard_normal((4 * GROUP,)).astype(np.float32)
    new = (base + rng.uniform(-0.01, 0.01, base.shape)
           .astype(np.float32)).astype(np.float32)
    src = DeltaLeafSource({"w": jnp.asarray(new)},
                          DeviceDeltaBase({"w": jnp.asarray(base)}),
                          codec="int8")
    payload = src.flat_payload()
    got = decode_ref(payload["q"], payload["s"])[:new.size]
    delta = new - base
    amax = np.abs(delta.reshape(-1, GROUP)).max(axis=1)
    bound = np.repeat(np.maximum(amax, 1e-12) / 254.0, GROUP)
    err = np.abs(got - delta)
    assert (err <= bound + 1e-9).all(), float((err - bound).max())


# ---------------------------------------------------------------------------
# skip paths: all-zero residual, fully-unchanged state
# ---------------------------------------------------------------------------

def test_all_zero_residual_skips_transfer_and_roundtrips(tmp_path):
    """When the fused per-leaf residual counts sum to zero the residual
    plane never crosses the link — the manifest carries a ``"zero"``
    marker, no ``flat@r.bin`` exists, the decoder reconstructs zeros, and
    restore is bit-exact."""
    rng = np.random.default_rng(11)
    base_w = rng.standard_normal((8 * GROUP,)).astype(np.float32)
    s0 = {"w": jnp.asarray(base_w), "t": np.arange(4, dtype=np.int64)}
    s1 = {"w": jnp.asarray(base_w + np.float32(1e-4)),
          "t": np.arange(4, dtype=np.int64)}
    src = DeltaLeafSource(s1, DeviceDeltaBase(s0), codec="lossless")
    payload = src.flat_payload()
    assert payload["r"] == "zero"
    # link = the d plane + the eager host-leaf copy; NO residual bytes
    assert src.bytes_on_link() == 8 * GROUP * 4 + s1["t"].nbytes
    base_np = jax.tree_util.tree_map(np.asarray, s0)
    d = str(tmp_path)
    write_delta(d, 1, src, base_np, 0, 1.0, mode="lossless", codec="zlib")
    meta = read_delta_manifest(d, 1)
    assert meta["flat"]["arrays"]["r"] == "zero"
    assert not os.path.exists(os.path.join(d, "delta_0000000001",
                                           "flat@r.bin"))
    got = apply_delta(d, 1, base_np, placement="device")
    assert _bit_exact(got, s1)


def test_all_unchanged_state_moves_no_payload(tmp_path):
    """Every packed leaf unchanged: no payload arrays cross the link at
    all, every packed leaf gets a skip-zero marker, and the delta still
    applies (to the base itself)."""
    s0 = _state(2)
    src = DeltaLeafSource(s0, DeviceDeltaBase(s0), codec="int8")
    assert src.flat_payload() == {}
    assert src.zero_names == tuple(src.layout.names)
    # only the eager host-leaf copies count as link traffic
    assert src.bytes_on_link() == s0["host"].nbytes + s0["ids"].nbytes
    base_np = jax.tree_util.tree_map(np.asarray, s0)
    write_delta(str(tmp_path), 1, src, base_np, 0, 1.0, mode="int8",
                codec="zlib")
    meta = read_delta_manifest(str(tmp_path), 1)
    assert meta["flat"]["arrays"] == {}
    got = apply_delta(str(tmp_path), 1, base_np)
    assert _bit_exact(got, s0)


# ---------------------------------------------------------------------------
# cross-version and mixed-dtype restores
# ---------------------------------------------------------------------------

def test_v2_per_leaf_manifest_restores_through_current_reader(tmp_path):
    """Cross-version: a per-leaf (pre-flat) delta manifest — no ``flat``
    section, the layout PR-5 wrote — still restores bit-exactly through
    the current reader under both decode placements."""
    s0 = _state(6)
    s1 = _bump(_state(6))
    base_np = jax.tree_util.tree_map(np.asarray, s0)
    new_np = jax.tree_util.tree_map(np.asarray, s1)
    d = str(tmp_path)
    write_delta(d, 1, new_np, base_np, 0, 1.0, mode="lossless",
                codec="zlib")
    meta = read_delta_manifest(d, 1)
    assert "flat" not in meta        # host pytree source: per-leaf blobs
    for placement in ("host", "device"):
        got = apply_delta(d, 1, base_np, placement=placement)
        assert _bit_exact(got, s1)


def test_mixed_dtype_state_roundtrips_bit_exact_under_device_plan(tmp_path):
    """Non-f32, odd-sized, zero-size and host-resident leaves fall back
    to the per-leaf host path while the f32 subtree rides the flat
    payload — one device-placement delta must restore the whole mixed
    state bit-exactly."""
    rng = np.random.default_rng(9)
    state = {
        "w": jnp.asarray(rng.standard_normal((3001,)).astype(np.float32)),
        "half": jnp.asarray(rng.standard_normal((513,))
                            .astype(np.float16)),
        "empty": jnp.zeros((0,), jnp.float32),
        "host": rng.standard_normal((65,)).astype(np.float32),
        "ids": np.arange(7, dtype=np.int64),
        "step": jnp.asarray(np.int32(0)),
    }
    bumped = dict(state)
    bumped["w"] = state["w"] + np.float32(1e-3)
    bumped["half"] = (state["half"].astype(jnp.float32)
                      + 0.25).astype(jnp.float16)
    bumped["host"] = state["host"] + np.float32(0.5)
    bumped["step"] = jnp.asarray(np.int32(1))
    plan = CheckpointPlan(mode="incremental", full_every=4,
                          encode_placement="device")
    mgr = CheckpointManager(str(tmp_path), plan)
    mgr.save(0, state, 0.0)
    rep = mgr.save(1, bumped, 1.0)
    assert rep.kind == "delta"
    meta = read_delta_manifest(str(tmp_path / "local"), 1)
    # only the non-empty f32 device leaf packs into the flat section
    assert [row[0] for row in meta["flat"]["layout"]] == ["w"]
    got = mgr.restore(state, "node")
    assert got.step == 1 and _bit_exact(got.state, bumped)


# ---------------------------------------------------------------------------
# cross-placement restore: blobs are byte-compatible both ways
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("save_placement,restore_placement",
                         [("device", "host"), ("host", "device")])
def test_cross_placement_restore_bit_exact(tmp_path, save_placement,
                                           restore_placement):
    plan_save = CheckpointPlan(mode="incremental", full_every=4,
                               encode_placement=save_placement)
    d = str(tmp_path / "ckpt")
    mgr = CheckpointManager(d, plan_save)
    s0, s1 = _state(0), _bump(_state(0))
    mgr.save(0, s0, 0.0)
    rep = mgr.save(1, s1, 1.0)
    assert rep.kind == "delta"
    meta = read_delta_manifest(os.path.join(d, "local"), 1)
    assert meta["placement"] == save_placement
    # restore through a manager configured for the OTHER placement
    mgr2 = CheckpointManager(d, CheckpointPlan(
        mode="incremental", full_every=4,
        encode_placement=restore_placement))
    got = mgr2.restore(_state(0), "node")
    assert got.step == 1 and got.kind == "full+delta"
    assert _bit_exact(got.state, s1)


@pytest.mark.parametrize("codec", ["lossless", "int8"])
def test_device_and_host_deltas_restore_identically(tmp_path, codec):
    """Acceptance: a fixed-seed device-encoded delta (flat mega-buffer
    blobs) and the host-encoded per-leaf delta of the same transition
    decode to the SAME state.  The blob layouts differ — ``flat@*.bin``
    planes under a ``flat`` manifest section subsume the packed leaves'
    per-leaf blobs — but GROUP alignment keeps every flat extent's
    payload bit-identical to the per-leaf encoder's, so the decoded
    states agree to the bit."""
    s0, s1 = _state(3), _bump(_state(3), eps=np.float32(2e-3))
    base = jax.tree_util.tree_map(np.asarray, s0)
    for placement in ("host", "device"):
        d = str(tmp_path / placement)
        os.makedirs(d)
        if placement == "device":
            src = DeltaLeafSource(s1, DeviceDeltaBase(s0), codec=codec)
        else:
            src = jax.tree_util.tree_map(np.asarray, s1)
        write_delta(d, 1, src, base, 0, 1.0, mode=codec, codec="zlib")
    mh = read_delta_manifest(str(tmp_path / "host"), 1)
    md = read_delta_manifest(str(tmp_path / "device"), 1)
    assert "flat" not in mh and md["flat"]
    # the packed subtree writes NO per-leaf blobs on the device path —
    # the flat planes replace them; fallback leaves still get their own
    dev_files = sorted(os.listdir(os.path.join(str(tmp_path / "device"),
                                               "delta_0000000001")))
    assert not any(f.startswith("params@") for f in dev_files)
    assert any(f.startswith("flat@") for f in dev_files)
    # skip-zero markers agree across placements (fused per-leaf change
    # counts == host byte-equality checks)
    assert set(mh["zero"]) == set(md["zero"])
    a = apply_delta(str(tmp_path / "host"), 1, base)
    b = apply_delta(str(tmp_path / "device"), 1, base, placement="device")
    assert _bit_exact(a, b)
    if codec == "lossless":
        assert _bit_exact(a, s1)


# ---------------------------------------------------------------------------
# device base lifecycle: plan-switch carry-over, failure wipe, savepoint
# ---------------------------------------------------------------------------

def test_plan_switch_carries_device_base_over(tmp_path):
    plan = CheckpointPlan(mode="incremental", full_every=8,
                          encode_placement="device")
    mgr = CheckpointManager(str(tmp_path), plan)
    s0 = _state(0)
    mgr.savepoint(0, s0, 0.0)
    assert mgr._device_base is not None
    # the rebuild (set_plan semantics): a fresh manager adopting runtime
    # state must keep device-encoding deltas against the drained full
    mgr2 = CheckpointManager(str(tmp_path), CheckpointPlan(
        mode="incremental", full_every=8, encode_placement="device",
        interval_s=10.0))
    mgr2.adopt_runtime_state(mgr)
    # the drained device base rides the rebuild (no re-upload)
    assert mgr2._device_base is mgr._device_base
    s1 = _bump(s0)
    rep = mgr2.save(1, s1, 1.0)      # trigger 0 of the new cadence: full
    assert rep.kind == "full"
    s2 = _bump(s1)
    rep = mgr2.save(2, s2, 2.0)
    assert rep.kind == "delta"
    meta = read_delta_manifest(str(tmp_path / "local"), 2)
    assert meta["placement"] == "device"
    got = mgr2.restore(_state(0), "node")
    assert got.step == 2 and _bit_exact(got.state, s2)
    # a node failure wipes the device base with the rest of runtime state
    mgr2.on_failure("node")
    assert mgr2._device_base is None
    rep2 = mgr2.save(3, s2, 3.0)
    assert rep2.kind == "full"          # chain restarts


def test_save_report_bytes_on_link_distinguishes_link_from_disk(tmp_path):
    """Satellite: bytes_on_link (pre-compression, post-encode) vs
    bytes_written (post-compression).  Host deltas move the raw state;
    device int8 deltas move ~0.3x of it."""
    s0 = _state(0, n=8192)
    s1 = _bump(s0)
    raw = sum(np.asarray(l).nbytes for l in jax.tree_util.tree_leaves(s0))
    host = CheckpointManager(str(tmp_path / "h"), CheckpointPlan(
        mode="incremental", full_every=4))
    host.save(0, s0, 0.0)
    rep = host.save(1, s1, 1.0)
    assert rep.kind == "delta" and rep.bytes_on_link == raw
    dev = CheckpointManager(str(tmp_path / "d"), CheckpointPlan(
        mode="incremental", full_every=4, encode_placement="device",
        delta_codec="int8"))
    full_rep = dev.save(0, s0, 0.0)
    assert full_rep.bytes_on_link == raw      # fulls always move the state
    drep = dev.save(1, s1, 1.0)
    assert drep.kind == "delta"
    assert 0 < drep.bytes_on_link < 0.5 * raw
    st = dev.stats()
    assert st["bytes_on_link"] == full_rep.bytes_on_link + drep.bytes_on_link
    # a device delta trigger that ALSO takes a remote full pulls the raw
    # state for that write — the raw D2H must be accounted, not just the
    # encoded payload
    ml = CheckpointManager(str(tmp_path / "ml"), CheckpointPlan(
        mode="incremental", full_every=4, levels=("local", "remote"),
        remote_every=2, encode_placement="device", delta_codec="int8"))
    ml.save(0, s0, 0.0)                       # full everywhere
    ml.save(1, _bump(s0), 1.0)                # delta, local only
    rep2 = ml.save(2, _bump(_bump(s0)), 2.0)  # delta local + remote FULL
    assert rep2.kind == "delta" and "remote" in rep2.levels
    assert rep2.bytes_on_link > raw           # payload + raw full pull
    # legacy incremental checkpointer reports the link quantity too
    from repro.checkpoint import CheckpointStore, IncrementalCheckpointer
    inc = IncrementalCheckpointer(CheckpointStore(str(tmp_path / "l"),
                                                  num_shards=2))
    inc.save(0, jax.tree_util.tree_map(np.asarray, s0))
    assert inc.stats()["bytes_on_link"] == raw


# ---------------------------------------------------------------------------
# cost model: placement pricing, v2 calibration, coverage assertions
# ---------------------------------------------------------------------------

def _v2_calibration():
    return {
        "schema": "bench_ckpt/2",
        "state_bytes": 32 * 2**20,
        "full_write_s": 2.0,
        "restore_s": 1.5,
        "delta_fraction": 0.05,
        "delta_int8_fraction": 0.01,
        "delta_encode_s_per_byte": 3.0 / (32 * 2**20),
        "device": {
            "lossless": {"bytes_on_link": 33 * 2**20 // 32,
                         "link_fraction": 1.01, "encode_s": 0.02},
            "int8": {"bytes_on_link": 8 * 2**20,
                     "link_fraction": 0.25, "encode_s": 0.01},
        },
        "plans": {"incr8-sync": {"bytes_per_trigger": 1.0, "write_s": 0.1,
                                 "blocking_s": 0.1, "encode_cpu_s": 0.5}},
    }


def test_from_calibration_v2_prices_device_placement():
    from repro.sim import SimCostModel

    cost = SimCostModel.from_calibration(_v2_calibration())
    assert cost.device_link_fraction_int8 == 0.25
    assert cost.device_encode_s == 0.02
    # device delta drops the per-trigger host encode (3 s) for the
    # measured device encode (0.01-0.02 s)
    host_d = cost.write_duration("delta", encoding="int8")
    dev_d = cost.write_duration("delta", encoding="int8",
                                placement="device")
    assert dev_d < host_d
    assert np.isclose(host_d - dev_d, 3.0 - 0.01)
    # plan-level: the device-int8 plan has the cheapest trigger average
    incr = CheckpointPlan(mode="incremental", full_every=8)
    dev8 = CheckpointPlan(mode="incremental", full_every=8,
                          encode_placement="device", delta_codec="int8")
    assert cost.avg_write_duration(dev8) < cost.avg_write_duration(incr)
    # link-bytes accounting: host plans move the raw state every trigger;
    # the device-int8 plan averages fulls at 1.0x with deltas at 0.25x
    assert cost.avg_link_bytes(incr) == cost.state_bytes
    want = (cost.state_bytes + 7 * 0.25 * cost.state_bytes) / 8
    assert np.isclose(cost.avg_link_bytes(dev8), want)
    # a delta trigger that also takes a remote full pays payload + raw
    dev_ml = CheckpointPlan(mode="incremental", full_every=8,
                            levels=("local", "remote"), remote_every=4,
                            encode_placement="device", delta_codec="int8")
    assert np.isclose(cost.trigger_link_bytes(dev_ml, 4),
                      1.25 * cost.state_bytes)


def _v3_calibration():
    cal = _v2_calibration()
    cal["schema"] = "bench_ckpt/3"
    for codec in ("lossless", "int8"):
        cal["device"][codec].update(pack_s=0.004, per_leaf_encode_s=0.5)
    return cal


def test_from_calibration_v3_prices_pack_and_rejects_missing_keys():
    from repro.sim import SimCostModel

    cost = SimCostModel.from_calibration(_v3_calibration())
    assert cost.device_pack_s == 0.004 == cost.device_pack_s_int8
    # the device delta price is pack + fused encode, replacing the host
    # per-byte encode term — exactly that swap, nothing double-charged
    host_d = cost.write_duration("delta", encoding="int8")
    dev_d = cost.write_duration("delta", encoding="int8",
                                placement="device")
    assert np.isclose(host_d - dev_d, 3.0 - (0.004 + 0.01))
    # a v2 artifact keeps pack_s at 0 (the per-leaf path packed nothing)
    assert SimCostModel.from_calibration(_v2_calibration()).device_pack_s \
        == 0.0
    # a v3 artifact missing the new per-codec keys is rejected
    bad = _v3_calibration()
    del bad["device"]["lossless"]["per_leaf_encode_s"]
    with pytest.raises(ValueError, match="device"):
        SimCostModel.from_calibration(bad)


def test_from_calibration_v1_fallback_and_v2_rejects_bad_device():
    from repro.sim import SimCostModel

    v1 = {k: v for k, v in _v2_calibration().items() if k != "device"}
    v1["schema"] = "bench_ckpt/1"
    cost = SimCostModel.from_calibration(v1)
    assert cost.device_link_fraction_int8 == \
        SimCostModel.device_link_fraction_int8   # modeled default
    bad = _v2_calibration()
    del bad["device"]["int8"]["encode_s"]
    with pytest.raises(ValueError, match="device"):
        SimCostModel.from_calibration(bad)
    bad2 = _v2_calibration()
    del bad2["device"]
    with pytest.raises(ValueError, match="device"):
        SimCostModel.from_calibration(bad2)


def test_surviving_levels_rejects_unknown_failure_kind():
    from repro.checkpoint.multilevel import allowed_levels
    from repro.sim import SimCostModel

    cost = SimCostModel()
    plan = CheckpointPlan(levels=("memory", "local", "remote"))
    assert cost.surviving_levels(plan, "node") == ("local", "remote")
    with pytest.raises(ValueError, match="unknown failure kind"):
        cost.surviving_levels(plan, "rack")
    with pytest.raises(ValueError, match="known kinds"):
        allowed_levels("typo")


# ---------------------------------------------------------------------------
# optimizer: (placement x codec) variants, campaign-verified
# ---------------------------------------------------------------------------

def test_optimize_plan_surfaces_campaign_verified_device_int8():
    """Acceptance: with a bench_ckpt/3-calibrated cost model (the device
    delta priced as pack + fused flat encode), the default variant grid
    contains (placement=device, codec=int8) candidates — single- and
    multi-level — and the campaign verifier scores at least one of them
    end-to-end."""
    from repro.core import QoSModel, optimize_plan
    from repro.core.ci_optimizer import default_plan_variants
    from repro.data.stream import constant_rate
    from repro.sim import SimCostModel
    from repro.sim.batched import make_plan_verifier

    cost = SimCostModel.from_calibration(
        _v3_calibration(), capacity_eps=4600.0, ckpt_sync_penalty=0.6)
    variants = default_plan_variants(cost, ci_ref=60.0)
    dev_int8 = [p for p in variants if p.encode_placement == "device"
                and p.delta_codec == "int8"]
    assert dev_int8, "variant grid lost the device-int8 dimension"
    assert any(tuple(p.levels) != ("local",) for p in dev_int8), \
        "variant grid lost the multi-level device-int8 plan"
    # each scored device candidate's modeled write price reflects the
    # fused cost: pack + one fused encode per delta trigger
    for p in dev_int8:
        assert np.isclose(
            cost.write_duration("delta", encoding="int8",
                                placement="device"),
            cost.ckpt_duration_s * cost.delta_int8_fraction
            + cost.device_pack_s_int8 + cost.device_encode_s_int8)
    rng = np.random.default_rng(0)
    ci = rng.uniform(10, 120, 200)
    tr = rng.uniform(1000, 4000, 200)
    m_l = QoSModel().fit(ci, tr, cost.base_latency_s + 40.0 / ci + tr * 1e-5)
    m_r = QoSModel().fit(ci, tr, 80.0 + 1.2 * ci + 0.01 * tr)
    verifier = make_plan_verifier(cost, schedule=constant_rate(2500.0),
                                  max_recovery_s=900.0)
    res = optimize_plan(m_l, m_r, tr_avg=2500.0, l_const=1.0, r_const=240.0,
                        p=1.0, ci_min=10, ci_max=120, cost=cost,
                        verifier=verifier, verify_top_k=4)
    assert res.feasible and res.verified
    scored = [c for c in res.candidates
              if c.plan.encode_placement == "device"
              and c.plan.delta_codec == "int8" and c.sim is not None]
    assert scored, "no device-int8 candidate was campaign-verified"
    assert {"latency_s", "recovery_s"} <= set(scored[0].sim)


# ---------------------------------------------------------------------------
# drive_campaign: shared QoS evaluation, Decisions bit-identical
# ---------------------------------------------------------------------------

def test_drive_campaign_batched_predictions_bit_identical_decisions():
    """Satellite: the per-period QoS-model reads are batched (one
    ``QoSModel.predict`` over all lanes), and the per-lane Decisions are
    BIT-identical to the per-lane evaluation loop."""
    from repro.config import KhaosConfig
    from repro.core import KhaosRuntime
    from repro.data.stream import constant_rate, dense_rates
    from repro.sim import BatchedCampaign, LaneSpec, SimCostModel
    from repro.sim.batched import BatchedLaneHandle

    cost = SimCostModel(capacity_eps=2600.0, ckpt_duration_s=1.0)
    kcfg = KhaosConfig(latency_constraint=1.2, recovery_constraint=240.0,
                       optimization_period=30.0, ci_min=10, ci_max=120,
                       reconfig_cooldown=60.0)
    sched = constant_rate(1800.0)

    def make_campaign():
        lanes = [LaneSpec(rates=dense_rates(0.0, 400, schedule=sched),
                          ci_s=float(ci),
                          failures=((120.0, "node"),) if i % 2 else ())
                 for i, ci in enumerate((15, 40, 80, 115))]
        return BatchedCampaign(cost, lanes)

    def fresh_runtime():
        rt = KhaosRuntime(kcfg, cost=cost)
        from repro.core.qos_models import QoSModel
        rng = np.random.default_rng(0)
        ci = rng.uniform(10, 120, 150)
        tr = rng.uniform(1000, 2400, 150)
        m_l = QoSModel().fit(ci, tr, cost.base_latency_s + 30.0 / ci)
        m_r = QoSModel().fit(ci, tr, 60.0 + 1.1 * ci + 0.02 * tr)
        rt.install_models(m_l, m_r)
        return rt

    # batched path: drive_campaign (shared predictions)
    rt = fresh_runtime()
    sup = rt.drive_campaign(make_campaign())

    # oracle: the pre-batching per-lane loop, scalar predict per lane
    rt2 = fresh_runtime()
    camp = make_campaign()
    handles = [BatchedLaneHandle(camp, i) for i in range(camp.n_lanes)]
    controllers = [rt2._make_controller() for _ in handles]
    period = max(1, int(round(kcfg.optimization_period)))
    while not camp.done:
        camp.run(n_ticks=period)
        for ctl, h in zip(controllers, handles):
            if h.alive():
                ctl.maybe_optimize(h)
    for ctl, h in zip(controllers, handles):
        ctl.maybe_optimize(h)

    for lane, (ctl, got) in enumerate(zip(controllers, sup.controllers)):
        want = ctl.decisions
        have = got.decisions
        assert len(want) == len(have), (lane, len(want), len(have))
        for dw, dh in zip(want, have):
            assert (dw.t, dw.kind) == (dh.t, dh.kind), lane
            for f in ("latency", "tr_avg", "predicted_recovery", "new_ci"):
                a, b = getattr(dw, f), getattr(dh, f)
                assert (a is None and b is None) or \
                    np.array_equal(np.float64(a), np.float64(b),
                                   equal_nan=True), (lane, f, a, b)
            assert (dw.new_plan is None) == (dh.new_plan is None)
            if dw.new_plan is not None:
                assert dw.new_plan == dh.new_plan
