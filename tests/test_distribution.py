"""Sharding rules (pure logic on an abstract mesh) + one real multi-device
compile in a subprocess (so the 1-device default of this test process is
preserved, per the dry-run isolation rule)."""
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import MeshConfig, ShardingConfig
from repro.configs import get_config
from repro.launch.mesh import make_abstract_mesh
from repro.sharding import ShardingRules


def _rules(arch="yi-6b", multi=False, **scfg):
    mesh = make_abstract_mesh(
        (2, 16, 16) if multi else (16, 16),
        ("pod", "data", "model") if multi else ("data", "model"))
    return ShardingRules(get_config(arch), mesh,
                         ShardingConfig(**scfg))


def test_tp_sharding_of_core_weights():
    r = _rules(fsdp=False)
    assert r.param_spec("layers/attn/wq", (32, 4096, 32, 128)) == P(None, None, "model", None)
    assert r.param_spec("layers/ffn/w_up", (32, 4096, 11008)) == P(None, None, "model")
    assert r.param_spec("layers/ffn/w_down", (32, 11008, 4096)) == P(None, "model", None)
    assert r.param_spec("emb/embed", (64000, 4096)) == P("model", None)


def test_kv_heads_replicated_when_not_divisible():
    r = _rules(fsdp=False)
    # yi-6b: 4 kv heads % 16 != 0 -> replicated (no head-dim sharding)
    assert r.param_spec("layers/attn/wk", (32, 4096, 4, 128)) == P(None, None, None, None)


def test_fsdp_adds_data_axis():
    r = _rules(fsdp=True, fsdp_min_params=0)
    spec = r.param_spec("layers/ffn/w_up", (32, 4096, 11008))
    assert spec == P(None, "data", "model")


def test_fsdp_spans_pod_axis_on_multipod():
    r = _rules(arch="grok-1-314b", multi=True, fsdp=True, fsdp_min_params=0)
    spec = r.param_spec("layers/moe/w_up", (64, 8, 6144, 32768))
    # experts (8) not divisible by tp: d over (pod,data), f over model
    assert spec == P(None, None, ("pod", "data"), "model")


def test_moe_expert_axis_when_divisible():
    r = _rules(arch="olmoe-1b-7b", fsdp=False)
    spec = r.param_spec("layers/moe/w_up", (16, 64, 2048, 1024))
    assert spec == P(None, "model", None, None)   # 64 experts / 16


def test_norms_replicated():
    r = _rules()
    assert r.param_spec("layers/ln1/scale", (32, 4096)) == P()


def test_kv_cache_seq_sharding_fallback():
    r = _rules()
    # yi decode: kv heads 4 %16 -> shard the 32k seq dim instead
    spec = r.cache_spec("k", (32, 128, 32768, 4, 128))
    assert spec == P(None, "data", "model", None, None)
    # codeqwen: 32 kv heads divisible -> heads shard
    r2 = _rules("codeqwen1.5-7b")
    spec2 = r2.cache_spec("k", (32, 128, 32768, 32, 128))
    assert spec2 == P(None, "data", None, "model", None)


def test_batch_replicates_when_not_divisible():
    r = _rules()
    assert r.input_spec("tokens", (1, 524288)) == P(None, None)   # long_500k B=1
    assert r.input_spec("tokens", (256, 4096)) == P("data", None)


def test_act_specs():
    r = _rules()
    assert r.act_spec("hidden", (256, 4096, 4096)) == P("data", None, None)
    assert r.act_spec("wide", (256, 4096, 11008)) == P("data", None, "model")


@pytest.mark.slow
def test_real_compile_on_8_fake_devices():
    """End-to-end lower+compile of a tiny sharded train step in a subprocess
    with 8 placeholder devices (never pollutes this process's jax)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, json
        from repro.config import ModelConfig, ShapeConfig, OptimizerConfig, ShardingConfig
        from repro.models import zoo
        from repro.optim import make_optimizer
        from repro.sharding import ShardingRules
        cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=128,
                          num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512)
        shape = ShapeConfig("t", "train", 64, 8)
        opt_cfg = OptimizerConfig(); opt = make_optimizer(opt_cfg)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = ShardingRules(cfg, mesh, ShardingConfig(fsdp_min_params=0))
        ann = rules.annotator()
        state = zoo.state_specs(cfg, opt)
        batch = zoo.input_specs(cfg, shape)
        fn = zoo.make_train_step(cfg, opt, opt_cfg, accum=2, ann=ann)
        out = jax.eval_shape(fn, state, batch)
        jt = jax.jit(fn,
                     in_shardings=(rules.state_shardings(state), rules.batch_shardings(batch)),
                     out_shardings=(rules.state_shardings(out[0]),
                                    jax.tree_util.tree_map(lambda _: rules.replicated(), out[1])))
        compiled = jt.lower(state, batch).compile()
        ma = compiled.memory_analysis()
        print(json.dumps({"ok": True, "temp": ma.temp_size_in_bytes}))
    """)
    try:
        res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                             text=True, timeout=300,
                             env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    except (subprocess.TimeoutExpired, OSError) as e:
        pytest.skip(f"8-device compile subprocess did not finish here: {e!r:.200}")
    if res.returncode != 0 and ("ImportError" in res.stderr
                                or "ModuleNotFoundError" in res.stderr):
        pytest.skip("8-device compile subprocess env is missing deps: "
                    + res.stderr[-500:])
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["ok"]
