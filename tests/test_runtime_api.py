"""The unified control-plane API: JobHandle protocol conformance across
every substrate, KhaosRuntime phase transitions, the TrainerJobHandle
drain + manager-rebuild plan switch, and Decision-kind integrity."""
import inspect

import numpy as np
import pytest

from repro.config import CheckpointPlan, KhaosConfig, OptimizerConfig
from repro.core import (Decision, KhaosController, KhaosRuntime,
                        missing_handle_methods, PhaseError, QoSModel)
from repro.data.stream import (EventStream, constant_rate, dense_rates,
                               record_workload)
from repro.sim import (BatchedCampaign, BatchedDeployment, BatchedLaneHandle,
                       LaneSpec, SimCostModel, SimJobHandle, StreamSimulator)

COST = SimCostModel(capacity_eps=2600.0, ckpt_duration_s=1.0)


def _prior_models(lo=10, hi=300):
    rng = np.random.default_rng(0)
    ci = rng.uniform(lo, hi, 150)
    tr = rng.uniform(800, 2200, 150)
    m_l = QoSModel().fit(ci, tr, COST.base_latency_s + 2.0 / ci)
    m_r = QoSModel().fit(ci, tr, 80 + 1.2 * ci + 0.02 * tr)
    return m_l, m_r


def _sim_handle():
    sim = StreamSimulator(COST, ci_s=60.0, schedule=constant_rate(1800.0))
    return SimJobHandle(sim)


def _lane_handle():
    lanes = [LaneSpec(rates=dense_rates(0.0, 200,
                                        schedule=constant_rate(1800.0)),
                      ci_s=60.0)]
    camp = BatchedCampaign(COST, lanes)
    camp.run(n_ticks=50)
    return BatchedLaneHandle(camp, 0)


def _trainer_handle(tmp_path):
    from repro.configs import get_smoke_config
    from repro.runtime import ResilientTrainer, TrainerConfig, TrainerJobHandle
    stream = EventStream(schedule=constant_rate(500.0))
    tcfg = TrainerConfig(batch=4, seq_len=16, ckpt_dir=str(tmp_path),
                         ckpt_interval_s=5.0, time_scale=20.0,
                         detect_s=1.0, restart_s=1.0)
    trainer = ResilientTrainer(get_smoke_config("yi-6b"), tcfg, stream,
                               OptimizerConfig(total_steps=1000, lr=1e-3))
    return TrainerJobHandle(trainer)


# ---------------------------------------------------------------------------
# protocol conformance — ONE shared test over every handle implementation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("factory", ["sim", "lane", "trainer"])
def test_job_handle_protocol_conformance(factory, tmp_path):
    """Every handle implements the complete protocol — same methods, sane
    return types — so the controller drives all substrates identically."""
    handle = {"sim": _sim_handle, "lane": _lane_handle,
              "trainer": lambda: _trainer_handle(tmp_path)}[factory]()
    missing = missing_handle_methods(handle)
    assert not missing, f"{type(handle).__name__} missing {missing}"
    assert np.isfinite(handle.now())
    assert handle.current_ci() > 0
    plan = handle.current_plan()
    assert isinstance(plan, CheckpointPlan)
    assert plan.interval_s == handle.current_ci()
    assert isinstance(handle.healthy(), bool)
    handle.avg_latency(30.0)            # may be NaN, must not raise
    handle.avg_throughput(30.0)
    handle.drain()                      # must be safe at any time
    handle.reconfigure(handle.current_ci())
    assert handle.reconfigurations


def test_controller_module_has_no_capability_probing():
    """The acceptance gate: the controller trusts the protocol — no
    getattr-based optional-method fallbacks anywhere in the module."""
    import repro.core.controller as controller
    assert "getattr" not in inspect.getsource(controller)


def test_decision_kinds_closed_set():
    assert set(Decision.KINDS) == {"none", "defer", "reconfigure",
                                   "proactive", "infeasible", "cooldown",
                                   "unhealthy"}
    with pytest.raises(AssertionError):
        Decision(0.0, "bogus", 0.0, 0.0, 0.0)


def test_controller_emits_only_documented_kinds():
    m_l, m_r = _prior_models()
    cfg = KhaosConfig(latency_constraint=1.0, recovery_constraint=240.0,
                      optimization_period=30.0, ci_min=10, ci_max=300,
                      reconfig_cooldown=60.0)
    sim = StreamSimulator(COST, ci_s=290.0, schedule=constant_rate(1800.0))
    sim.inject_failure(200.0)
    job = SimJobHandle(sim)
    ctl = KhaosController(cfg=cfg, m_l=m_l, m_r=m_r, cost=COST)
    while sim.t < 900.0:
        sim.tick()
        ctl.maybe_optimize(job)
    assert ctl.decisions
    assert {d.kind for d in ctl.decisions} <= set(Decision.KINDS)


# ---------------------------------------------------------------------------
# KhaosRuntime phase machine
# ---------------------------------------------------------------------------

def _tiny_recording():
    return record_workload(constant_rate(1800.0), duration=900, seed=0)


def test_runtime_phases_in_order():
    kcfg = KhaosConfig(num_failure_points=2, num_configs=2,
                       ci_min=20, ci_max=90)
    rt = KhaosRuntime(kcfg)
    rec = _tiny_recording()
    rt.record_steady_state(rec)
    assert rt.phase == "steady_state"
    rt.run_profiling(BatchedDeployment(COST, rec, warmup_s=120,
                                       max_recovery_s=600.0), margin=60)
    assert rt.phase == "profiled"
    assert rt.m_l is not None and rt.m_r is not None
    ctl = rt.attach(_sim_handle())
    assert rt.phase == "optimizing"
    assert isinstance(ctl, KhaosController)
    assert rt.phase_sequence() == ["steady_state", "profiled", "optimizing"]


def test_runtime_rejects_out_of_order_phases():
    kcfg = KhaosConfig(num_failure_points=2, num_configs=2)
    rec = _tiny_recording()
    with pytest.raises(PhaseError):
        KhaosRuntime(kcfg).run_profiling(BatchedDeployment(COST, rec))
    with pytest.raises(PhaseError):
        KhaosRuntime(kcfg).attach(_sim_handle())
    with pytest.raises(PhaseError):
        KhaosRuntime(kcfg).step()
    rt = KhaosRuntime(kcfg)
    rt.record_steady_state(rec)
    with pytest.raises(PhaseError):         # phase 1 cannot repeat
        rt.record_steady_state(rec)
    m_l, m_r = _prior_models()
    with pytest.raises(PhaseError):         # install_models only from idle
        rt.install_models(m_l, m_r)


def test_runtime_install_models_skips_but_logs_phases():
    m_l, m_r = _prior_models()
    rt = KhaosRuntime(KhaosConfig())
    rt.install_models(m_l, m_r)
    assert rt.phase == "profiled"
    assert [ev.phase for ev in rt.phase_log] == ["steady_state", "profiled"]
    assert all(ev.info.get("skipped") for ev in rt.phase_log)
    job = _sim_handle()
    rt.attach(job)
    sim = job.sim
    while sim.t < 100.0:
        sim.tick()
        rt.step()
    assert rt.controller.decisions


def test_runtime_rejects_incomplete_handle():
    m_l, m_r = _prior_models()
    rt = KhaosRuntime(KhaosConfig())
    rt.install_models(m_l, m_r)

    class Partial:                          # the old duck-typed shape
        def now(self): return 0.0
        def current_ci(self): return 60.0
        def avg_latency(self, w): return 0.1
        def avg_throughput(self, w): return 1000.0
        def healthy(self): return True
        def reconfigure(self, ci): pass

    with pytest.raises(TypeError, match="reconfigure_plan"):
        rt.attach(Partial())


# ---------------------------------------------------------------------------
# controller-in-the-loop batched campaigns (Phase 3, vectorized)
# ---------------------------------------------------------------------------

def test_drive_campaign_lane_matches_scalar_controlled_run():
    """A controller-in-the-loop lane polled every tick is bit-exact against
    the scalar sim + controller loop — including a mechanism switch."""
    m_l, m_r = _prior_models()
    cfg = KhaosConfig(latency_constraint=1.0, recovery_constraint=240.0,
                      optimization_period=30.0, ci_min=10, ci_max=300,
                      reconfig_cooldown=60.0)
    T = 901    # (T-1) % period == 0: a decision falls due exactly at the
               # final tick, exercising the post-loop poll
    # scalar oracle (mechanism search active: decisions carry plans)
    sim = StreamSimulator(COST, ci_s=290.0, schedule=constant_rate(1800.0))
    job = SimJobHandle(sim)
    ctl = KhaosController(cfg=cfg, m_l=m_l, m_r=m_r, cost=COST)
    while sim.t < T:
        sim.tick()
        ctl.maybe_optimize(job)
    assert job.plan_changes, "scenario must exercise a plan switch"
    # campaign twin
    rt = KhaosRuntime(cfg, cost=COST)
    rt.install_models(m_l, m_r)
    lanes = [LaneSpec(rates=dense_rates(0.0, T,
                                        schedule=constant_rate(1800.0)),
                      ci_s=290.0)]
    camp = BatchedCampaign(COST, lanes)
    sup = rt.drive_campaign(camp, period_ticks=1)
    h = sup.handles[0]
    assert h.reconfigurations == job.reconfigurations
    assert h.plan_changes == job.plan_changes
    np.testing.assert_array_equal(
        np.array(sim.metrics.series("consumer_lag").values),
        camp.lag_hist[0])
    assert camp.lane_plan(0).name == sim.plan.name
    assert camp.interval[0] == sim.policy.interval_s
    assert [(d.t, d.kind) for d in sup.controllers[0].decisions] \
        == [(d.t, d.kind) for d in ctl.decisions]


def test_drive_campaign_supervises_selected_lanes_only():
    m_l, m_r = _prior_models()
    cfg = KhaosConfig(latency_constraint=1.0, recovery_constraint=240.0,
                      optimization_period=30.0, ci_min=10, ci_max=300,
                      reconfig_cooldown=60.0)
    rt = KhaosRuntime(cfg)
    rt.install_models(m_l, m_r)
    T = 400
    lanes = [LaneSpec(rates=dense_rates(0.0, T,
                                        schedule=constant_rate(1800.0)),
                      ci_s=290.0) for _ in range(3)]
    camp = BatchedCampaign(COST, lanes)
    sup = rt.drive_campaign(camp, lanes=[1])
    assert camp.done
    assert sup.summary()["lanes"] == 1
    assert sup.reconfigurations(1)          # supervised lane acted
    # unsupervised lanes kept their CI
    assert camp.interval[0] == 290.0 and camp.interval[2] == 290.0
    assert camp.interval[1] != 290.0


# ---------------------------------------------------------------------------
# TrainerJobHandle: live drain + manager rebuild
# ---------------------------------------------------------------------------

NEW_PLAN = CheckpointPlan(interval_s=3.0, mode="incremental", full_every=2,
                          levels=("memory", "local"), sync=False,
                          num_shards=2)


def test_trainer_reconfigure_plan_drains_and_rebuilds(tmp_path):
    """State survives a plan switch mid-run: the drain checkpoint lands
    under the OLD plan, the next checkpoint under the NEW plan, and a
    failure after the switch restores the drained state."""
    job = _trainer_handle(tmp_path)
    tr = job.tr
    tr.run(duration_s=12.0)
    old_manager = tr.ckpt
    old_plan_name = tr.ckpt.plan.name
    step_at_switch = int(tr.state["step"])
    job.reconfigure_plan(NEW_PLAN)
    # drain happened: the OLD manager persisted the pre-switch step
    assert old_manager.stats()["saves"] >= 1
    assert tr.ckpt is not old_manager, "manager must be rebuilt"
    assert tr.ckpt.plan.name == NEW_PLAN.name
    assert tr.policy is tr.ckpt.policy, "policy clock must carry over"
    assert tr.policy.interval_s == NEW_PLAN.interval_s
    assert job.plan_changes and job.plan_changes[0][1] == NEW_PLAN.name
    # metrics-window continuity: the same store keeps pre-switch samples
    assert len(tr.metrics.series("latency")) > 0
    # training continues and the next checkpoint lands under the new plan
    tr.run(duration_s=12.0)
    summary = tr.summary()
    assert summary["plan_switches"] == 1
    assert int(tr.state["step"]) > step_at_switch
    st = summary["ckpt_stats"]
    assert st["plan"] == NEW_PLAN.name
    assert st["saves"] >= 1
    post_switch_ckpts = [e for e in tr.events
                         if e["event"] == "checkpoint"
                         and e["t"] > job.plan_changes[0][0]]
    assert post_switch_ckpts, "no checkpoint landed under the new plan"
    assert any("memory" in e["levels"] for e in post_switch_ckpts)
    # a failure after the switch restores from the new plane's state
    tr.inject_failure_at(tr.t + 2.0)
    tr.run(duration_s=15.0)
    summary = tr.summary()
    assert summary["restores"] >= 1
    assert int(tr.state["step"]) >= step_at_switch, \
        "restore lost the drained progress"


def test_controller_decision_switches_trainer_plan_mid_run(tmp_path):
    """The acceptance scenario: a live ResilientTrainer run switches
    checkpoint plans mid-run via a controller Decision."""
    from repro.core import RescalingTracker

    job = _trainer_handle(tmp_path)
    tr = job.tr
    # models that violate the recovery constraint at the starting CI but
    # admit feasible (plan, CI) points lower in the window
    rng = np.random.default_rng(1)
    ci = rng.uniform(2, 60, 120)
    trr = rng.uniform(100, 800, 120)
    m_l = QoSModel().fit(ci, trr, 0.05 + 0.4 / ci)
    m_r = QoSModel().fit(ci, trr, 5.0 + 1.2 * ci + 0.005 * trr)
    cost = SimCostModel(capacity_eps=500.0, ckpt_duration_s=0.5)
    rt = KhaosRuntime(
        KhaosConfig(latency_constraint=1.0, recovery_constraint=20.0,
                    optimization_period=4.0, ci_min=2, ci_max=60,
                    reconfig_cooldown=8.0),
        cost=cost, mtbf_s=600.0)
    rt.install_models(m_l, m_r)
    rt.attach(job)

    class FixedP(RescalingTracker):
        """Pin the localization factor: the micro trainer's measured
        latency has nothing to do with the installed prior models, and
        this test exercises the actuation path, not the model fit."""
        @property
        def p(self) -> float:
            return 1.0

    rt.controller.rescaler = FixedP()
    tr.set_ci(50.0)     # start far above the feasible region
    tr.run(duration_s=30.0, on_second=lambda s: rt.step())
    switches = [d for d in rt.controller.decisions
                if d.kind == "reconfigure" and d.new_plan is not None]
    assert switches, "controller never issued a plan-switch Decision"
    assert job.plan_changes
    assert tr.ckpt.plan.name == switches[-1].new_plan.name
    assert tr.summary()["plan_switches"] >= 1
    assert {d.kind for d in rt.controller.decisions} <= set(Decision.KINDS)


def test_drain_persists_under_sparse_level_cadences(tmp_path):
    """drain() must be cadence-exempt: under a plan whose disk level only
    writes every Nth trigger, a cadence-gated save could land memory-only
    and the plan-switch rebuild would then lose the drained progress."""
    from repro.configs import get_smoke_config
    from repro.runtime import ResilientTrainer, TrainerConfig, TrainerJobHandle
    stream = EventStream(schedule=constant_rate(500.0))
    sparse = CheckpointPlan(interval_s=4.0, levels=("memory", "local"),
                            local_every=4, num_shards=2)
    tcfg = TrainerConfig(batch=4, seq_len=16, ckpt_dir=str(tmp_path),
                         time_scale=20.0, detect_s=1.0, restart_s=1.0,
                         plan=sparse)
    tr = ResilientTrainer(get_smoke_config("yi-6b"), tcfg, stream,
                          OptimizerConfig(total_steps=1000, lr=1e-3))
    job = TrainerJobHandle(tr)
    tr.run(duration_s=6.0)      # trigger count sits mid-cadence
    drained_step = int(tr.state["step"])
    job.reconfigure_plan(CheckpointPlan(interval_s=5.0, num_shards=2))
    assert tr.ckpt.stats()["plan"] == "full-sync"
    # a node failure right after the switch (memory level gone) must
    # restore the drained step from disk, not an older cadence-gated write
    tr.inject_failure_at(tr.t + 0.1)
    tr.run(duration_s=8.0)
    restore = next(e for e in tr.events if e["event"] == "restore")
    assert restore["step"] >= drained_step, \
        "drain savepoint was not durable across the plan switch"


# ---------------------------------------------------------------------------
# eager_snapshot knob (donated-buffer states)
# ---------------------------------------------------------------------------

def test_eager_snapshot_disables_deferred_transfer(tmp_path, monkeypatch):
    import jax.numpy as jnp

    import repro.checkpoint.manager as manager_mod
    from repro.checkpoint import CheckpointManager
    from repro.checkpoint.pipeline import ChunkedHostSnapshot

    seen = []

    class Spy(ChunkedHostSnapshot):
        def __init__(self, state, chunk_bytes, defer_device=True):
            seen.append(defer_device)
            super().__init__(state, chunk_bytes, defer_device=defer_device)

    monkeypatch.setattr(manager_mod, "ChunkedHostSnapshot", Spy)
    state = {"w": jnp.arange(64, dtype=jnp.float32),
             "step": np.int64(3)}
    for eager in (False, True):
        plan = CheckpointPlan(levels=("memory", "local"), sync=False,
                              num_shards=1, eager_snapshot=eager)
        mgr = CheckpointManager(str(tmp_path / f"eager{eager}"), plan)
        mgr.save(1, state, 0.0)
        mgr.wait()
        report = mgr.restore(state, "task")
        np.testing.assert_array_equal(np.asarray(report.state["w"]),
                                      np.asarray(state["w"]))
    assert seen == [True, False]
