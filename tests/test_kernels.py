"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # offline env: fixed-seed fallback below
    HAVE_HYPOTHESIS = False

from repro.kernels.ckpt_delta.ops import delta_decode, delta_encode
from repro.kernels.ckpt_delta.ref import GROUP, decode_ref, encode_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru.ops import rglru_scan
from repro.kernels.rglru.ref import rglru_ref
from repro.kernels.rwkv6.ops import wkv6
from repro.kernels.rwkv6.ref import wkv6_ref


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("B,S,H,K,hd", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 4, 2, 64),     # GQA
    (1, 256, 8, 1, 128),    # MQA, MXU-width head
    (1, 512, 2, 2, 256),    # RG-style 256 head dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_sweep(B, S, H, K, hd, dtype, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_kv=64, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_softcap():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))
    out = flash_attention(q, k, v, causal=True, softcap=30.0,
                          block_q=64, block_kv=64, interpret=True)
    ref = attention_ref(q, k, v, causal=True, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


@pytest.mark.parametrize("B,S,D,ct,bd", [
    (1, 128, 128, 64, 128),
    (2, 512, 256, 128, 128),
    (1, 256, 512, 256, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_sweep(B, S, D, ct, bd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    a = (jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, D))) * 0.2 + 0.79).astype(dtype)
    b = (jax.random.normal(ks[1], (B, S, D)) * 0.1).astype(dtype)
    h0 = (jax.random.normal(ks[2], (B, D)) * 0.5).astype(jnp.float32)
    out = rglru_scan(a, b, h0, chunk_t=ct, block_d=bd, interpret=True)
    ref = rglru_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **_tol(dtype))


@pytest.mark.parametrize("B,S,H,hs,ct", [
    (1, 128, 2, 16, 64),
    (2, 256, 2, 32, 128),
    (1, 128, 4, 64, 32),
])
def test_wkv6_sweep(B, S, H, hs, ct):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    r, k, v = (jax.random.normal(kk, (B, S, H, hs)) * 0.5 for kk in ks[:3])
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, hs))) * 0.3 + 0.65
    u = jax.random.normal(ks[4], (H, hs)) * 0.3
    s0 = jnp.zeros((B, H, hs, hs))
    y, s = wkv6(r, k, v, w, u, s0, chunk_t=ct, interpret=True)
    yr, sr = wkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=2e-4)


def test_wkv6_state_carry_matches_two_chunks():
    """Running S=256 in one call == two sequential 128-calls via s carry."""
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    B, S, H, hs = 1, 256, 2, 16
    r, k, v = (jax.random.normal(kk, (B, S, H, hs)) * 0.5 for kk in ks[:3])
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, hs))) * 0.3 + 0.65
    u = jax.random.normal(ks[4], (H, hs)) * 0.3
    s0 = jnp.zeros((B, H, hs, hs))
    y_all, s_all = wkv6(r, k, v, w, u, s0, chunk_t=64, interpret=True)
    y1, s1 = wkv6(r[:, :128], k[:, :128], v[:, :128], w[:, :128], u, s0,
                  chunk_t=64, interpret=True)
    y2, s2 = wkv6(r[:, 128:], k[:, 128:], v[:, 128:], w[:, 128:], u, s1,
                  chunk_t=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y_all[:, 128:]), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_all), np.asarray(s2), atol=1e-4)


@pytest.mark.parametrize("n", [1024, 4096, 5000, 100_000])
def test_ckpt_delta_kernel_vs_ref(n):
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    new = jax.random.normal(ks[0], (n,))
    base = new + jax.random.normal(ks[1], (n,)) * 0.01
    q, s = delta_encode(new, base, interpret=True)
    qr, sr = encode_ref(np.asarray(new) - np.asarray(base))
    np.testing.assert_allclose(np.asarray(s), sr, rtol=1e-6)
    assert np.mean(np.asarray(q) == qr) > 0.999   # round ties may differ
    d = delta_decode(q, s, interpret=True)[:n]
    dr = decode_ref(qr, sr)[:n]
    np.testing.assert_allclose(np.asarray(d), dr, atol=1e-6)


@pytest.mark.parametrize("n", [1024, 4096, 5000])
def test_ckpt_lossless_kernel_bit_exact_vs_ref(n):
    """The fused lossless sub+XOR-residual kernel must match its host
    oracle bit for bit, and decode must reproduce the original f32 bit
    patterns exactly (this is what keeps lossless restore bit-exact when
    the encode runs on-device)."""
    from repro.kernels.ckpt_delta.ops import lossless_decode, lossless_encode
    from repro.kernels.ckpt_delta.ref import (lossless_decode_ref,
                                              lossless_encode_ref)

    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    new = jax.random.normal(ks[0], (n,))
    base = new + jax.random.normal(ks[1], (n,)) * 1e-3
    d, r = lossless_encode(new, base, interpret=True)
    dr, rr = lossless_encode_ref(np.asarray(new), np.asarray(base))
    assert np.array_equal(np.asarray(d)[:n], dr)
    assert np.array_equal(np.asarray(r)[:n], rr)
    # kernel decode: original bit patterns back, exactly
    out = np.asarray(lossless_decode(base, d, r, interpret=True))[:n]
    assert np.array_equal(out.view(np.uint32),
                          np.asarray(new).view(np.uint32))
    # host oracle decode agrees bitwise too
    out_ref = lossless_decode_ref(np.asarray(base), dr, rr)
    assert np.array_equal(out_ref.view(np.uint32),
                          np.asarray(new).view(np.uint32))
    # the u32 residual's bytes equal the legacy per-byte u8 XOR, so blobs
    # written by either path stay mutually restorable
    pred = np.asarray(base) + dr
    legacy = np.frombuffer(np.asarray(new).tobytes(), np.uint8) \
        ^ np.frombuffer(pred.tobytes(), np.uint8)
    assert legacy.tobytes() == rr.tobytes()


def _check_ckpt_delta_roundtrip_error_bound(n, scale, seed):
    """Property: |delta - decode(encode(delta))| <= group_scale/2 elementwise."""
    rng = np.random.default_rng(seed)
    delta = (rng.standard_normal(n) * scale).astype(np.float32)
    q, s = encode_ref(delta)
    rec = decode_ref(q, s)[:n]
    group_scales = np.repeat(s, GROUP)[:n]
    assert np.all(np.abs(delta - rec) <= group_scales / 2 + 1e-9)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(8, 5000), scale=st.floats(1e-4, 1e3),
           seed=st.integers(0, 2**16))
    def test_ckpt_delta_roundtrip_error_bound(n, scale, seed):
        _check_ckpt_delta_roundtrip_error_bound(n, scale, seed)
else:
    @pytest.mark.parametrize("n,scale,seed", [
        (8, 1e-4, 0), (1023, 0.3, 7), (1024, 1.0, 42), (1025, 17.0, 123),
        (4096, 1e3, 2**16), (5000, 2.5, 31337)])
    def test_ckpt_delta_roundtrip_error_bound(n, scale, seed):
        _check_ckpt_delta_roundtrip_error_bound(n, scale, seed)
