"""Device-engine parity: ``DeviceCampaign`` must reproduce the NumPy
``BatchedCampaign`` bit-for-bit across the full plan x crash-kind x
degradation-kind matrix (the device twin of test_batched_sim's
lane-vs-scalar matrix), survive mid-run actuation, drive under the
Phase-3 controller loop, and power ``optimize_plan``'s exhaustive sweep.

One shared campaign pair runs the whole matrix (XLA compiles are the
expensive part, not lanes), and the assertions are ``assert_array_equal``
— no tolerances anywhere in this file.
"""
import numpy as np
import pytest

from repro.config import CheckpointPlan, KhaosConfig
from repro.core import KhaosRuntime, QoSModel, optimize_plan
from repro.data.stream import constant_rate, dense_rates
from repro.ft.failures import Degradation
from repro.sim import (BatchedCampaign, LaneSpec, SimCostModel,
                       make_campaign, make_plan_verifier)
from repro.sim.device import DeviceCampaign, fma_contraction_active

COST = SimCostModel(capacity_eps=4600.0, base_latency_s=0.5,
                    ckpt_duration_s=3.0, ckpt_sync_penalty=0.6)
PLANS = (None,
         CheckpointPlan(sync=False),
         CheckpointPlan(mode="incremental", full_every=8, sync=False),
         CheckpointPlan(mode="incremental", full_every=4,
                        levels=("memory", "local", "remote"),
                        local_every=1, remote_every=8))
KINDS = ("task", "node", "cluster")
DEGRADATIONS = (
    Degradation(t=300.0, kind="straggler", duration_s=400.0, severity=1.8),
    Degradation(t=250.0, kind="net_delay", duration_s=500.0, severity=3.0,
                jitter_s=0.8, direction="to_source"),
    Degradation(t=250.0, kind="net_delay", duration_s=600.0, severity=4.0,
                jitter_s=1.0, direction="to_ckpt_store"),
    Degradation(t=200.0, kind="backpressure", duration_s=150.0),
)
T = 900
RATES = 3000.0 + 800.0 * np.sin(np.arange(T) / 40.0)

FINAL_STATE = ("lag", "consumed", "produced", "processed_total",
               "ckpt_count", "save_count", "steady_lag", "down", "t",
               "off_lvl")


def _matrix_lanes() -> list[LaneSpec]:
    lanes = []
    for pi, plan in enumerate(PLANS):
        for kind in KINDS:
            for ci in (15.0, 45.0):
                lanes.append(LaneSpec(
                    rates=RATES, ci_s=ci, plan=plan,
                    failures=((200.0 + 20 * pi, kind), (560.0, "task"))))
    for plan in PLANS:
        for deg in DEGRADATIONS:
            for fails in ((), ((400.0, "task"),)):
                lanes.append(LaneSpec(rates=RATES, ci_s=20.0, plan=plan,
                                      failures=fails, degradations=[deg]))
    for plan in PLANS:        # no-failure lanes: the recovery-free carry
        lanes.append(LaneSpec(rates=RATES, ci_s=25.0, plan=plan))
    return lanes


@pytest.fixture(scope="module")
def matrix():
    lanes = _matrix_lanes()
    a = BatchedCampaign(COST, lanes).run()
    b = DeviceCampaign(COST, lanes).run()
    return a, b


def test_fma_contraction_pinned_off():
    """conftest pins --xla_cpu_max_isa=AVX; without it, LLVM contracts f64
    mul-add chains into FMAs and every bit-exact assertion below would be
    1 ULP off."""
    assert fma_contraction_active() is False


def test_matrix_lag_history_bitexact(matrix):
    a, b = matrix
    np.testing.assert_array_equal(a.lag_hist, b.lag_hist)


def test_matrix_latency_history_bitexact(matrix):
    a, b = matrix
    np.testing.assert_array_equal(a.latency_history(), b.latency_history())


def test_matrix_final_state_bitexact(matrix):
    a, b = matrix
    for name in FINAL_STATE:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
            err_msg=name)


def test_matrix_recoveries_identical(matrix):
    a, b = matrix
    assert a.recoveries == b.recoveries
    assert any(a.recoveries), "matrix must actually exercise recoveries"


def test_midrun_plan_and_ci_switch_bitexact():
    """Actuation between run() calls — the drive_campaign contract — must
    leave both engines in the same state, including the flink-semantics
    savepoint restart the plan switch triggers."""
    Ts = 600
    rates = RATES[:Ts]
    lanes = [LaneSpec(rates=rates, ci_s=60.0,
                      failures=((150.0, "node"),)) for _ in range(4)]
    a = BatchedCampaign(COST, lanes)
    b = DeviceCampaign(COST, lanes)
    for camp in (a, b):
        camp.run(n_ticks=300)
        camp.lane_set_plan(1, CheckpointPlan(mode="incremental",
                                             full_every=8, sync=False))
        camp.lane_set_ci(2, 20.0)
        camp.run()
    np.testing.assert_array_equal(a.lag_hist, b.lag_hist)
    for name in FINAL_STATE:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
            err_msg=name)
    assert a.recoveries == b.recoveries
    assert a.lane_plan(1).name == b.lane_plan(1).name


def test_drive_campaign_device_matches_numpy():
    """The Phase-3 controller loop produces identical decisions and lane
    trajectories on either engine underneath."""
    rng = np.random.default_rng(0)
    ci = rng.uniform(10, 300, 150)
    tr = rng.uniform(800, 2200, 150)
    cost = SimCostModel(capacity_eps=2600.0, ckpt_duration_s=1.0)
    m_l = QoSModel().fit(ci, tr, cost.base_latency_s + 2.0 / ci)
    m_r = QoSModel().fit(ci, tr, 80 + 1.2 * ci + 0.02 * tr)
    cfg = KhaosConfig(latency_constraint=1.0, recovery_constraint=240.0,
                      optimization_period=30.0, ci_min=10, ci_max=300,
                      reconfig_cooldown=60.0)
    Ts = 601
    lanes = [LaneSpec(rates=dense_rates(0.0, Ts,
                                        schedule=constant_rate(1800.0)),
                      ci_s=290.0)]
    sups = {}
    for engine in ("numpy", "device"):
        rt = KhaosRuntime(cfg, cost=cost)
        rt.install_models(m_l, m_r)
        camp = make_campaign(cost, lanes, engine=engine)
        sups[engine] = (rt.drive_campaign(camp), camp)
    (sup_n, camp_n), (sup_d, camp_d) = sups["numpy"], sups["device"]
    assert isinstance(camp_d, DeviceCampaign)
    assert sup_n.handles[0].reconfigurations == \
        sup_d.handles[0].reconfigurations
    assert sup_n.handles[0].plan_changes == sup_d.handles[0].plan_changes
    np.testing.assert_array_equal(camp_n.lag_hist, camp_d.lag_hist)


def test_optimize_plan_exhaustive_device_matches_or_improves_topk():
    """The exhaustive device sweep replays every feasible variant; since
    its measurements are bit-identical to the NumPy verifier's, its pick
    must match or improve the top-k pick's MEASURED objective."""
    cost = SimCostModel(capacity_eps=2600.0, ckpt_duration_s=1.5)
    rng = np.random.default_rng(0)
    ci = rng.uniform(10, 120, 200)
    tr = rng.uniform(1000, 2200, 200)
    m_l = QoSModel().fit(ci, tr, cost.base_latency_s + 40.0 / ci + tr * 1e-5)
    m_r = QoSModel().fit(ci, tr, 80.0 + 1.2 * ci + 0.01 * tr)
    kw = dict(tr_avg=1500.0, l_const=2.0, r_const=600.0, p=1.0,
              ci_min=10, ci_max=120, cost=cost, grid=32)

    ver = make_plan_verifier(cost, schedule=constant_rate(1500.0),
                             warmup_s=60.0, max_recovery_s=600.0)
    res_top = optimize_plan(m_l, m_r, verifier=ver, verify_top_k=2, **kw)

    ver = make_plan_verifier(cost, schedule=constant_rate(1500.0),
                             warmup_s=60.0, max_recovery_s=600.0)
    res_ex = optimize_plan(m_l, m_r, verifier=ver, exhaustive=True,
                           engine="device", **kw)
    assert ver.engine == "device"        # optimize_plan(engine=) set it

    def measured(res):
        return {c.plan.name: c.sim["objective"] for c in res.candidates
                if c.sim is not None and c.sim["feasible"]}

    top_m, ex_m = measured(res_top), measured(res_ex)
    assert set(top_m) <= set(ex_m), \
        "exhaustive replay must cover the top-k shortlist"
    # identical measurements for the shared candidates (device parity)
    for name, obj in top_m.items():
        assert ex_m[name] == obj
    assert res_ex.verified and res_top.verified
    # the measured-objective gate: exhaustive can only match or improve
    assert min(ex_m.values()) <= min(top_m.values())
    assert ex_m[res_ex.plan.name] <= top_m[res_top.plan.name]


def test_make_campaign_factory_and_lazy_export():
    lanes = [LaneSpec(rates=RATES[:100], ci_s=30.0)]
    assert type(make_campaign(COST, lanes)) is BatchedCampaign
    assert type(make_campaign(COST, lanes, engine="device")) \
        is DeviceCampaign
    with pytest.raises(ValueError, match="unknown campaign engine"):
        make_campaign(COST, lanes, engine="cuda")
    import repro.sim
    assert repro.sim.DeviceCampaign is DeviceCampaign
