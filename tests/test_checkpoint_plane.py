"""The unified checkpoint plane: CheckpointManager layer composition
(delta encoding x level routing x sync/async commit), failure-kind-aware
restore, and the plan optimizer over mechanism variants."""
import json
import os
import shutil
import time

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, CheckpointPlan
from repro.checkpoint.incremental import newest_delta_step, read_delta_manifest
from repro.checkpoint.store import resolve_codec
from repro.config import CheckpointConfig
from repro.utils.trees import tree_allclose


def _state(seed=0, n=500):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w1": rng.standard_normal((n, 8)).astype(np.float32),
                   "w2": rng.standard_normal((n,)).astype(np.float32)},
        "opt": {"m": rng.standard_normal((n, 8)).astype(np.float32)},
        "step": np.int32(seed),
    }


def _bit_exact(a, b) -> bool:
    la = [np.asarray(x) for x in
          __import__("jax").tree_util.tree_leaves(a)]
    lb = [np.asarray(x) for x in
          __import__("jax").tree_util.tree_leaves(b)]
    return all(np.array_equal(x, y) for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# crash-mid-delta falls back to the base full snapshot
# ---------------------------------------------------------------------------

def test_crash_mid_delta_falls_back_to_base_full(tmp_path):
    plan = CheckpointPlan(mode="incremental", full_every=8, levels=("local",))
    mgr = CheckpointManager(str(tmp_path), plan)
    s0, s1 = _state(0), _state(1)
    mgr.save(0, s0, 0.0)                       # full
    r1 = mgr.save(1, s1, 1.0)                  # delta
    assert r1.kind == "delta"
    local = str(tmp_path / "local")
    # crash scenario A: the write died before publish — only a .tmp dir
    ddir = os.path.join(local, "delta_0000000002.tmp")
    os.makedirs(ddir)
    with open(os.path.join(ddir, "params@w1.bin"), "wb") as f:
        f.write(b"partial")
    assert newest_delta_step(local) == 1       # .tmp invisible
    # crash scenario B: delta dir exists but its manifest never landed
    shutil.rmtree(os.path.join(local, "delta_0000000001"))
    os.rename(ddir, os.path.join(local, "delta_0000000002"))
    rep = mgr.restore(_state(0), "node")
    assert rep.step == 0 and rep.kind == "full"
    assert tree_allclose(rep.state, s0)


# ---------------------------------------------------------------------------
# multilevel + delta composition restores bit-exact in lossless mode
# ---------------------------------------------------------------------------

def test_multilevel_delta_lossless_bit_exact(tmp_path):
    plan = CheckpointPlan(mode="incremental", full_every=3,
                          delta_codec="lossless",
                          levels=("memory", "local", "remote"),
                          local_every=1, remote_every=3)
    mgr = CheckpointManager(str(tmp_path), plan)
    states = [_state(i) for i in range(5)]
    for i, s in enumerate(states):
        mgr.save(i, s, float(i), extra={"i": i})
    # node failure wipes memory; local restores full_3 + delta_4 bit-exact
    mgr.on_failure("node")
    rep = mgr.restore(_state(0), "node")
    assert (rep.step, rep.level, rep.kind) == (4, "local", "full+delta")
    assert _bit_exact(rep.state, states[4])
    assert rep.extra["i"] == 4
    # cluster failure: only the remote fulls survive (steps 0 and 3)
    mgr.on_failure("cluster")
    rep = mgr.restore(_state(0), "cluster")
    assert (rep.step, rep.level, rep.kind) == (3, "remote", "full")
    assert _bit_exact(rep.state, states[3])


def test_delta_manifest_records_codec(tmp_path):
    plan = CheckpointPlan(mode="incremental", full_every=4, levels=("local",))
    mgr = CheckpointManager(str(tmp_path), plan)
    mgr.save(0, _state(0), 0.0)
    mgr.save(1, _state(1), 1.0)
    meta = read_delta_manifest(str(tmp_path / "local"), 1)
    assert meta["codec"] == resolve_codec("auto")
    # explicit zlib plans work everywhere and restore picks zlib back up
    plan2 = CheckpointPlan(mode="incremental", full_every=4,
                          levels=("local",), codec="zlib")
    mgr2 = CheckpointManager(str(tmp_path / "z"), plan2)
    s0, s1 = _state(3), _state(4)
    mgr2.save(0, s0, 0.0)
    mgr2.save(1, s1, 1.0)
    meta = read_delta_manifest(str(tmp_path / "z" / "local"), 1)
    assert meta["codec"] == "zlib"
    rep = mgr2.restore(_state(0), "node")
    assert rep.step == 1 and _bit_exact(rep.state, s1)


# ---------------------------------------------------------------------------
# async commit ordering: a manifest is never visible ahead of its shards
# ---------------------------------------------------------------------------

def test_async_commit_never_publishes_manifest_before_shards(tmp_path):
    plan = CheckpointPlan(sync=False, busy_policy="block", num_shards=4)
    mgr = CheckpointManager(str(tmp_path), plan)
    local = tmp_path / "local"
    big = {"w": np.random.default_rng(0).standard_normal((400_000,))
           .astype(np.float32)}
    violations = []
    for step in range(3):
        mgr.save(step, big, float(step))
        # poll the directory while the background write is in flight: any
        # published manifest must already validate against all its shards
        deadline = time.monotonic() + 10.0
        while mgr._committer.busy and time.monotonic() < deadline:
            for name in os.listdir(local):
                mdir = local / name
                if not name.startswith("step_") or name.endswith(".tmp"):
                    continue
                mpath = mdir / "manifest.json"
                if not mpath.exists():
                    violations.append(f"{name}: dir visible without manifest")
                    continue
                manifest = json.loads(mpath.read_text())
                for shard in manifest["checksums"]:
                    if not (mdir / shard).exists():
                        violations.append(f"{name}: manifest ahead of {shard}")
        mgr.wait()
    assert not violations, violations
    assert mgr.stats()["async_errors"] == []
    rep = mgr.restore({"w": np.zeros(400_000, np.float32)}, "node")
    assert rep.step == 2


def test_async_busy_skip_counts_and_recovers(tmp_path):
    plan = CheckpointPlan(sync=False, busy_policy="skip", num_shards=2)
    mgr = CheckpointManager(str(tmp_path), plan)
    big = {"w": np.zeros((2_000_000,), np.float32)}
    reports = [mgr.save(i, big, float(i)) for i in range(4)]
    mgr.wait()
    kinds = [r.kind for r in reports]
    assert kinds[0] != "skipped"
    assert mgr.stats()["skips"] == kinds.count("skipped")
    # whatever landed is restorable
    rep = mgr.restore(big, "node")
    assert rep.step >= 0


# ---------------------------------------------------------------------------
# failure_kind routing picks the fastest surviving level
# ---------------------------------------------------------------------------

def test_failure_kind_routing_fastest_surviving_level(tmp_path):
    plan = CheckpointPlan(levels=("memory", "local", "remote"),
                          local_every=1, remote_every=1)
    mgr = CheckpointManager(str(tmp_path), plan)
    s = _state(7)
    mgr.save(7, s, 0.0)
    # all three levels hold step 7: the fastest surviving one must win
    assert mgr.restore(_state(0), "task").level == "memory"
    assert mgr.restore(_state(0), "node").level == "local"
    assert mgr.restore(_state(0), "cluster").level == "remote"
    # a fresher memory snapshot beats older disk levels for task failures
    plan2 = CheckpointPlan(levels=("memory", "local"), local_every=4)
    mgr2 = CheckpointManager(str(tmp_path / "b"), plan2)
    for i in range(3):
        mgr2.save(i, _state(i), float(i))
    rep = mgr2.restore(_state(0), "task")
    assert (rep.step, rep.level) == (2, "memory")
    rep = mgr2.restore(_state(0), "node")       # memory doesn't survive
    assert (rep.step, rep.level) == (0, "local")


def test_nothing_survives_raises(tmp_path):
    plan = CheckpointPlan(levels=("memory", "local"))
    mgr = CheckpointManager(str(tmp_path), plan)
    mgr.save(0, _state(0), 0.0)
    mgr.on_failure("cluster")
    with pytest.raises(FileNotFoundError):
        mgr.restore(_state(0), "cluster")


# ---------------------------------------------------------------------------
# pipelined save path: aliasing isolation, blocking budget, zero markers
# ---------------------------------------------------------------------------

def test_pipelined_async_restore_bit_exact_under_inplace_mutation(tmp_path):
    """The aliasing hazard the chunked snapshot must preserve: mutable host
    arrays are deep-copied before save() returns, so an in-place mutation
    racing the in-flight background write never leaks into the restore."""
    plan = CheckpointPlan(sync=False, busy_policy="block", num_shards=2,
                          chunk_bytes=1 << 16)
    mgr = CheckpointManager(str(tmp_path), plan)
    rng = np.random.default_rng(0)
    state = {"w": rng.standard_normal((512, 512)).astype(np.float32),
             "b": rng.standard_normal((512,)).astype(np.float32)}
    want = {k: v.copy() for k, v in state.items()}
    mgr.save(3, state, 0.0)
    state["w"] *= -1.0          # racing in-place mutation
    state["b"][:] = 0.0
    mgr.wait()
    assert mgr.stats()["async_errors"] == []
    rep = mgr.restore({"w": np.zeros((512, 512), np.float32),
                       "b": np.zeros((512,), np.float32)}, "node")
    assert rep.step == 3 and _bit_exact(rep.state, want)


def test_chunked_snapshot_source_matches_state():
    """ChunkedHostSnapshot materializes jax leaves chunk by chunk in the
    background but as_pytree()/get() must reproduce the state bit-exactly
    and survive later mutation of host leaves."""
    import jax.numpy as jnp

    from repro.checkpoint import ChunkedHostSnapshot
    rng = np.random.default_rng(1)
    state = {"dev": jnp.asarray(rng.standard_normal((64, 1024))
                                .astype(np.float32)),
             "host": rng.standard_normal((256,)).astype(np.float32),
             "step": np.int32(9)}
    want_host = state["host"].copy()
    snap = ChunkedHostSnapshot(state, chunk_bytes=16 << 10)
    state["host"][:] = -1.0
    got = snap.as_pytree()
    assert _bit_exact(got["dev"], state["dev"])
    assert _bit_exact(got["host"], want_host)
    assert int(got["step"]) == 9
    assert snap.spec("dev") == ((64, 1024), np.dtype(np.float32))


def test_async_incremental_blocking_below_half_duration(tmp_path):
    """Regression: the pipelined save must keep the caller-blocking part of
    an async incremental trigger well under the total write work on a
    multi-MB state (pre-pipeline, blocking == the full deep copy).  The
    leaves are immutable jax Arrays so the save exercises the deferred
    chunked-transfer path, not just the eager host-copy one."""
    import jax.numpy as jnp

    plan = CheckpointPlan(mode="incremental", full_every=4, sync=False,
                          busy_policy="block", num_shards=2,
                          chunk_bytes=1 << 20)
    mgr = CheckpointManager(str(tmp_path), plan)
    rng = np.random.default_rng(2)
    state = {"w": jnp.asarray(rng.standard_normal((2_000_000,))
                              .astype(np.float32))}
    mgr.save(0, state, 0.0)             # full
    mgr.wait()
    bumped = {"w": state["w"] + np.float32(1e-4)}
    rep = mgr.save(1, bumped, 1.0)      # delta: encode+compress dominates
    mgr.wait()
    assert mgr.stats()["async_errors"] == []
    assert rep.kind == "delta" and not rep.synchronous
    assert rep.duration_s > 0.0
    assert rep.blocking_s < rep.duration_s / 2, \
        (rep.blocking_s, rep.duration_s)
    assert rep.encode_s > 0.0           # the calibration quantity
    # and the delta restores bit-exact through the pipelined path
    got = mgr.restore({"w": np.zeros(2_000_000, np.float32)}, "node")
    assert got.step == 1 and _bit_exact(got.state, bumped)


def test_write_delta_zero_marker_for_unchanged_leaf(tmp_path):
    """An unchanged leaf is recorded as a manifest "zero" marker: no blob
    on disk, fewer payload bytes, bit-exact restore."""
    plan = CheckpointPlan(mode="incremental", full_every=8, levels=("local",))
    mgr = CheckpointManager(str(tmp_path), plan)
    rng = np.random.default_rng(3)
    s0 = {"hot": rng.standard_normal((4096,)).astype(np.float32),
          "frozen": rng.standard_normal((4096,)).astype(np.float32),
          "ids": np.arange(128, dtype=np.int64)}
    mgr.save(0, s0, 0.0)
    s1 = {"hot": s0["hot"] + np.float32(0.5),
          "frozen": s0["frozen"].copy(),        # unchanged
          "ids": s0["ids"].copy()}              # unchanged, non-float
    rep = mgr.save(1, s1, 1.0)
    assert rep.kind == "delta"
    local = str(tmp_path / "local")
    meta = read_delta_manifest(local, 1)
    assert set(meta["zero"]) == {"frozen", "ids"}
    ddir = os.path.join(local, "delta_0000000001")
    assert not os.path.exists(os.path.join(ddir, "frozen.bin"))
    assert not os.path.exists(os.path.join(ddir, "ids.bin"))
    assert os.path.exists(os.path.join(ddir, "hot.bin"))
    rep = mgr.restore({k: np.zeros_like(v) for k, v in s0.items()}, "node")
    assert rep.step == 1 and _bit_exact(rep.state, s1)


# ---------------------------------------------------------------------------
# calibration loop: BENCH_ckpt.json -> SimCostModel.from_calibration
# ---------------------------------------------------------------------------

def _calibration(encode_per_byte=0.0):
    return {
        "schema": "bench_ckpt/1",
        "state_bytes": 32 * 2**20,
        "full_write_s": 2.0,
        "restore_s": 1.5,
        "delta_fraction": 0.05,
        "delta_int8_fraction": 0.01,
        "delta_encode_s_per_byte": encode_per_byte,
        "plans": {"incr8-sync": {"bytes_per_trigger": 1.0, "write_s": 0.1,
                                 "blocking_s": 0.1, "encode_cpu_s": 0.5}},
    }


def test_cost_model_from_calibration_prices_encode_cpu():
    from repro.sim import SimCostModel

    free = SimCostModel.from_calibration(_calibration(0.0),
                                         capacity_eps=2000.0)
    assert free.ckpt_duration_s == 2.0 and free.restore_s == 1.5
    assert free.delta_fraction == 0.05 and free.capacity_eps == 2000.0
    # measured encode CPU makes every delta write dearer by bytes * rate
    rate = 3.0 / (32 * 2**20)           # 3 s of host encode per trigger
    paid = SimCostModel.from_calibration(_calibration(rate))
    assert np.isclose(paid.write_duration("delta") -
                      free.write_duration("delta"), 3.0)
    assert paid.write_duration("full") == free.write_duration("full")
    # encode CPU above the write win: incremental loses its advantage
    incr = CheckpointPlan(mode="incremental", full_every=8)
    full = CheckpointPlan()
    assert free.avg_write_duration(incr) < free.avg_write_duration(full)
    assert paid.avg_write_duration(incr) > paid.avg_write_duration(full)


def test_cost_model_from_calibration_rejects_bad_artifacts():
    from repro.sim import SimCostModel

    with pytest.raises(ValueError):
        SimCostModel.from_calibration({"schema": "bench_ckpt/1"})
    bad = _calibration()
    bad["schema"] = "bench_ckpt/999"
    with pytest.raises(ValueError):
        SimCostModel.from_calibration(bad)
    with pytest.raises(TypeError):
        SimCostModel.from_calibration(_calibration(), not_a_field=1.0)


# ---------------------------------------------------------------------------
# config + plan plumbing
# ---------------------------------------------------------------------------

def test_checkpoint_config_lowers_to_plan():
    cfg = CheckpointConfig(mode="async", incremental=True, full_every=4,
                           levels=("memory", "local"))
    plan = cfg.to_plan()
    assert plan.mode == "incremental" and not plan.sync
    assert plan.full_every == 4 and plan.levels == ("memory", "local")
    assert "incr4" in plan.name and "async" in plan.name


def test_plan_optimizer_beats_full_sync_baseline():
    """Acceptance: the cross-product search returns a different (mode, CI)
    plan than full-sync, at lower modeled overhead, while both are
    QoS-feasible."""
    from repro.core import QoSModel, optimize_plan
    from repro.sim import SimCostModel

    rng = np.random.default_rng(0)
    ci = rng.uniform(10, 120, 200)
    tr = rng.uniform(1000, 4000, 200)
    cost = SimCostModel(capacity_eps=4600.0, ckpt_duration_s=3.0,
                        ckpt_sync_penalty=0.6)
    m_l = QoSModel().fit(ci, tr, cost.base_latency_s + 40.0 / ci + tr * 1e-5)
    m_r = QoSModel().fit(ci, tr, 80.0 + 1.2 * ci + 0.01 * tr)
    res = optimize_plan(m_l, m_r, tr_avg=2500.0, l_const=1.0, r_const=240.0,
                        p=1.0, ci_min=10, ci_max=120, cost=cost)
    assert res.feasible and res.baseline.feasible
    assert res.plan.name != res.baseline.plan.name      # mechanism switched
    assert res.overhead < res.baseline.overhead         # cheaper plane
    assert res.objective <= res.baseline.objective


def test_sim_plan_changes_recovery_semantics():
    """Cluster failure with a local-only plan replays from zero; adding a
    remote level bounds the rollback to the last remote full."""
    from repro.data.stream import constant_rate
    from repro.sim import SimCostModel, StreamSimulator

    cost = SimCostModel(capacity_eps=3000.0, ckpt_duration_s=1.0)
    base = CheckpointPlan()
    ml = CheckpointPlan(levels=("memory", "local", "remote"), remote_every=4)
    consumed_at_restart = {}
    for name, plan in [("local", base), ("ml", ml)]:
        sim = StreamSimulator(cost, ci_s=30.0, schedule=constant_rate(1000.0),
                              plan=plan)
        sim.inject_failure(200.0, kind="cluster")
        sim.run_until(190.0)
        before = sim.consumed
        sim.run_until(500.0)
        consumed_at_restart[name] = (before, sim.pending_restore_offset)
        assert sim.recoveries or sim._active_failure is not None or True
    # local-only: cluster failure loses everything -> offset rolled to 0
    # (pending offset is consumed during restart; compare via recoveries)
    sim_local = StreamSimulator(cost, ci_s=30.0,
                                schedule=constant_rate(1000.0), plan=base)
    sim_local.inject_failure(200.0, kind="cluster")
    sim_local.run_until(201.0)
    assert sim_local.pending_restore_offset == 0.0
    sim_ml = StreamSimulator(cost, ci_s=30.0, schedule=constant_rate(1000.0),
                             plan=ml)
    sim_ml.inject_failure(200.0, kind="cluster")
    sim_ml.run_until(201.0)
    assert sim_ml.pending_restore_offset > 0.0


def test_controller_switches_mechanism_on_sim():
    """Integration: with a cost model attached the controller's decision
    carries a plan and the sim actually switches to it."""
    from repro.config import KhaosConfig
    from repro.core import KhaosController, QoSModel
    from repro.data.stream import constant_rate
    from repro.sim import SimCostModel, SimJobHandle, StreamSimulator

    rng = np.random.default_rng(0)
    ci = rng.uniform(10, 300, 150)
    tr = rng.uniform(800, 2200, 150)
    cost = SimCostModel(capacity_eps=2600.0, ckpt_duration_s=1.0)
    m_l = QoSModel().fit(ci, tr, cost.base_latency_s + 2.0 / ci)
    m_r = QoSModel().fit(ci, tr, 80 + 1.2 * ci + 0.02 * tr)
    cfg = KhaosConfig(latency_constraint=1.0, recovery_constraint=240.0,
                      optimization_period=30.0, ci_min=10, ci_max=300,
                      reconfig_cooldown=60.0)
    sim = StreamSimulator(cost, ci_s=290.0, schedule=constant_rate(1800.0))
    job = SimJobHandle(sim)
    ctl = KhaosController(cfg=cfg, m_l=m_l, m_r=m_r, cost=cost)
    while sim.t < 900.0:
        sim.tick()
        ctl.maybe_optimize(job)
    reconf = [d for d in ctl.decisions if d.kind == "reconfigure"]
    assert reconf, "controller never acted"
    assert reconf[0].new_plan is not None
    assert job.plan_changes
    assert sim.plan.name == reconf[-1].new_plan.name
