"""Data substrate: streams, lag accounting, deterministic batching, cursors."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # offline env: fixed-seed fallback below
    HAVE_HYPOTHESIS = False

from repro.data import (EventStream, StreamingBatcher, WorkloadRecording,
                        constant_rate, ctr_rate, diurnal_rate, record_workload)


def test_stream_lag_accounting():
    s = EventStream(schedule=constant_rate(100.0))
    s.produce_until(0.0)
    s.produce_until(10.0)
    assert abs(s.produced - 1000.0) < 1.0
    got = s.consume(400)
    assert got == 400
    assert s.lag == int(s.produced) - 400
    assert s.consume(10_000) == s.produced // 1 - 400 or s.lag == 0


def test_stream_time_monotonic():
    s = EventStream(schedule=constant_rate(10.0))
    s.produce_until(5.0)
    with pytest.raises(ValueError):
        s.produce_until(4.0)


def test_recording_smoothing_reduces_variance():
    rec = record_workload(constant_rate(1000.0), duration=600, seed=0)
    raw = rec.workload(1)
    smooth = rec.workload(30)
    assert smooth.std() < 0.5 * raw.std()
    assert abs(smooth.mean() - raw.mean()) / raw.mean() < 0.02


def test_rate_schedules_positive_and_variable():
    for sched in (diurnal_rate(base=1000, seed=1), ctr_rate(base=2000, seed=2)):
        rates = np.array([sched(t) for t in np.linspace(0, 86400, 500)])
        assert np.all(rates > 0)
        assert rates.max() > 1.3 * rates.min()


def test_batcher_requires_full_batch_and_tracks_lag():
    s = EventStream(schedule=constant_rate(10.0))
    b = StreamingBatcher(s, global_batch=8, seq_len=16, vocab=100)
    s.produce_until(0.5)          # ~5 events < 8
    assert b.next_batch() is None
    s.produce_until(2.0)          # ~20 events
    batch = b.next_batch()
    assert batch is not None
    assert batch["tokens"].shape == (8, 16)
    assert batch["labels"].shape == (8, 16)
    assert np.array_equal(batch["tokens"][:, 1:], batch["labels"][:, :-1])


def test_batcher_cursor_restore_is_exactly_once():
    """Restoring the checkpointed cursor reproduces the identical batch
    sequence — the exactly-once property (DESIGN.md §7.7)."""
    def run(restore_at, total):
        s = EventStream(schedule=constant_rate(1000.0))
        b = StreamingBatcher(s, global_batch=4, seq_len=8, vocab=50, seed=9)
        s.produce_until(100.0)
        out, saved = [], None
        for i in range(total):
            if i == restore_at and saved is not None:
                b.restore(saved)      # roll back mid-run
            if i == restore_at - 2:
                saved = b.state_dict()
            out.append(b.next_batch()["tokens"])
        return out

    plain = run(restore_at=10**9, total=6)
    rolled = run(restore_at=4, total=8)
    # rolled-back run repeats batches 2,3 then continues identically
    np.testing.assert_array_equal(rolled[4], plain[2])
    np.testing.assert_array_equal(rolled[5], plain[3])
    np.testing.assert_array_equal(rolled[6], plain[4])


def _check_event_tokens_deterministic(seed, offset):
    """Property: token content depends only on (seed, offset)."""
    from repro.data.pipeline import _tokens_for_events
    a = _tokens_for_events(np.array([offset]), 16, 1000, seed)
    b = _tokens_for_events(np.array([offset]), 16, 1000, seed)
    c = _tokens_for_events(np.array([offset + 1]), 16, 1000, seed)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.min() >= 0 and a.max() < 1000


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 1000), offset=st.integers(0, 10_000))
    def test_event_tokens_deterministic_by_offset(seed, offset):
        _check_event_tokens_deterministic(seed, offset)
else:
    @pytest.mark.parametrize("seed,offset", [
        (0, 0), (1, 1), (7, 123), (42, 4096), (999, 9_999), (1000, 10_000)])
    def test_event_tokens_deterministic_by_offset(seed, offset):
        _check_event_tokens_deterministic(seed, offset)
