"""Khaos core algorithm tests (phases 1-3)."""
import numpy as np
import pytest

from repro.config import KhaosConfig
from repro.core import (AnomalyDetector, OnlineARIMA, QoSModel,
                        RescalingTracker, WorkloadForecaster, optimize_ci,
                        select_failure_points, young_daly_interval)
from repro.data.stream import diurnal_rate, record_workload


# -- online ARIMA -------------------------------------------------------------

def test_arima_tracks_trend_and_seasonality():
    m = OnlineARIMA(p=8, d=1, lr=0.1)
    errs = []
    for t in range(800):
        y = 50 + 0.05 * t + 10 * np.sin(t / 15)
        _, e = m.update(y)
        if t > 200:
            errs.append(abs(e) / max(abs(y), 1e-9))
    assert np.mean(errs) < 0.02


def test_arima_forecast_shape_and_finiteness():
    m = OnlineARIMA(p=6, d=1)
    for t in range(100):
        m.update(100 + np.sin(t / 7))
    fc = m.forecast(10)
    assert fc.shape == (10,)
    assert np.all(np.isfinite(fc))


def test_arima_forecast_follows_ramp():
    m = OnlineARIMA(p=8, d=1, lr=0.1)
    for t in range(600):
        m.update(1000 + 5 * t)
    fc = m.forecast(5)
    assert fc[-1] > fc[0]          # keeps rising
    assert abs(fc[0] - (1000 + 5 * 600)) / (1000 + 5 * 600) < 0.25


# -- phase 1 -----------------------------------------------------------------

def test_failure_point_selection_spans_throughput_range():
    rec = record_workload(diurnal_rate(base=1000, amplitude=0.8, period=7200),
                          duration=7200, seed=0)
    ss = select_failure_points(rec, m=5, smoothing_window=30)
    w = ss.smoothed
    assert len(ss.failure_times) == 5
    # selected rates approximately cover [min, max]
    assert ss.failure_rates.min() <= w.min() + 0.15 * (w.max() - w.min())
    assert ss.failure_rates.max() >= w.max() - 0.15 * (w.max() - w.min())
    # equidistant levels
    lv = np.sort(ss.failure_rates)
    gaps = np.diff(lv)
    assert gaps.max() < 2.5 * max(gaps.min(), 1e-9)


def test_failure_point_time_mode_eq4_literal():
    rec = record_workload(diurnal_rate(base=1000, period=7200),
                          duration=7200, seed=1)
    ss = select_failure_points(rec, m=4, smoothing_window=30, mode="time")
    f = ss.failure_times
    assert len(f) == 4
    gaps = np.diff(np.sort(f))
    assert np.allclose(gaps, gaps[0], rtol=0.05)       # equidistant timestamps


# -- anomaly detector -----------------------------------------------------------

def test_anomaly_detector_measures_disruption():
    det = AnomalyDetector()
    rng = np.random.default_rng(0)
    for t in range(600):
        thr = 1000 + 30 * np.sin(t / 20) + rng.normal(0, 5)
        lag = 50 + 5 * np.sin(t / 10) + rng.normal(0, 2)
        if 400 <= t < 460:
            thr, lag = 0.0, 50 + 200 * (t - 399)
        det.observe(t, {"throughput": thr, "consumer_lag": lag},
                    learn=not (400 <= t < 520))
    assert det.recoveries, "failure not detected"
    start, end = det.recoveries[-1]
    assert 380 <= start <= 420
    assert (end - start) >= 55


def test_anomaly_detector_recovery_interval_bookkeeping():
    # the streak hysteresis and the (start, end) ledger, deterministically:
    # an isolated spike shorter than min_anomaly_len opens nothing; a
    # sustained excursion opens at the streak threshold and closes only
    # after recovery_normal_len clean samples, recording the interval
    # error_window=30: the cold-start predictions are ~0, so their relative
    # errors are astronomical — a wide window would still hold them here and
    # inflate the 3-sigma threshold beyond any real excursion
    det = AnomalyDetector(metrics=("throughput",), min_anomaly_len=2,
                          recovery_normal_len=3, error_window=30)
    for t in range(60):
        det.observe(float(t), {"throughput": 100.0})
    assert det.warmed_up and not det.anomalous

    det.observe(60.0, {"throughput": 1e4}, learn=False)   # single blip
    for t in (61, 62, 63):
        det.observe(float(t), {"throughput": 100.0})
    assert not det.recoveries and not det.anomalous
    assert det.last_recovery_time() is None

    for t in (64, 65):                                     # sustained: opens
        det.observe(float(t), {"throughput": 1e4}, learn=False)
    assert det.anomalous
    for t in (66, 67, 68):                                 # clean run: closes
        det.observe(float(t), {"throughput": 100.0})
    assert not det.anomalous
    assert det.recoveries == [(65.0, 68.0)]
    assert det.last_recovery_time() == 3.0


def test_anomaly_detector_quiet_on_steady_stream():
    det = AnomalyDetector(threshold_sigma=5.0)
    rng = np.random.default_rng(1)
    for t in range(500):
        det.observe(t, {"throughput": 1000 + rng.normal(0, 10),
                        "consumer_lag": 50 + rng.normal(0, 3)})
    assert not det.recoveries


# -- phase 3 models ----------------------------------------------------------

def test_qos_model_fit_quality_and_error_analysis():
    rng = np.random.default_rng(2)
    ci = rng.uniform(10, 120, 80)
    tr = rng.uniform(500, 3000, 80)
    y = 40 + 1.1 * ci + 0.02 * tr + 1e-4 * ci * tr + rng.normal(0, 1.5, 80)
    m = QoSModel(degree=2).fit(ci, tr, y)
    assert m.avg_percent_error(ci, tr, y) < 0.05
    pred = m.predict(np.array([60.0]), 1500.0)
    truth = 40 + 1.1 * 60 + 0.02 * 1500 + 1e-4 * 60 * 1500
    assert abs(pred[0] - truth) / truth < 0.1


def test_rescaling_tracker_mean_of_fractions():
    rt = RescalingTracker(k=3)
    for obs, pred in [(1.0, 2.0), (2.0, 2.0), (3.0, 2.0), (4.0, 2.0)]:
        rt.track(obs, pred)
    assert abs(rt.p - np.mean([1.0, 1.5, 2.0])) < 1e-9   # window of 3


def test_eq8_optimizer_prefers_balanced_feasible_ci():
    rng = np.random.default_rng(3)
    ci = rng.uniform(10, 120, 100)
    tr = rng.uniform(500, 3000, 100)
    lat = 0.3 + 8.0 / ci                                  # low CI -> high latency
    rec = 60 + 2.0 * ci                                   # high CI -> slow recovery
    m_l = QoSModel().fit(ci, tr, lat)
    m_r = QoSModel().fit(ci, tr, rec)
    res = optimize_ci(m_l, m_r, tr_avg=1500, l_const=1.0, r_const=240,
                      p=1.0, ci_min=10, ci_max=120)
    assert res.feasible
    assert 10 <= res.ci <= 120
    assert res.q_r < 1 and res.q_l < 1
    # the objective balances: |Q_R - Q_L| should be small at the optimum
    assert abs(res.q_r - res.q_l) < 0.25


def test_eq8_optimizer_reports_infeasible():
    rng = np.random.default_rng(4)
    ci = rng.uniform(10, 120, 50)
    tr = rng.uniform(500, 3000, 50)
    m_l = QoSModel().fit(ci, tr, np.full(50, 5.0))    # always above l_const=1
    m_r = QoSModel().fit(ci, tr, 60 + 2 * ci)
    res = optimize_ci(m_l, m_r, 1500, 1.0, 240, 1.0, 10, 120)
    assert not res.feasible and res.ci is None


# -- TSF deferral --------------------------------------------------------------

def test_forecaster_defers_on_forecasted_drop():
    f = WorkloadForecaster(horizon=5, defer_drop_fraction=0.10)
    # steep relative decline: 5-step-ahead drop is ~25% of the current level
    for t in range(80):
        f.observe(3000 - 30.0 * t)
    assert f.should_defer()


def test_forecaster_no_defer_on_stable_load():
    f = WorkloadForecaster(horizon=5, defer_drop_fraction=0.10)
    rng = np.random.default_rng(5)
    for t in range(400):
        f.observe(2000 + rng.normal(0, 10))
    assert not f.should_defer()


def test_forecaster_cold_start_is_inert():
    # before warm-up the forecast is meaningless: no deferral, and
    # predicted_peak degenerates to the last observation so the proactive
    # rule falls back to reactive behavior instead of acting on noise
    f = WorkloadForecaster(horizon=5)
    assert not f.warmed_up
    assert not f.should_defer()
    assert f.predicted_peak() == 0.0          # nothing observed yet
    f.observe(1800.0)
    assert not f.warmed_up
    assert f.predicted_peak() == 1800.0
    assert not f.should_defer()
    # a warmed model fed only zeros still refuses to defer (_last <= 0)
    z = WorkloadForecaster(horizon=5)
    for _ in range(100):
        z.observe(0.0)
    assert z.warmed_up and not z.should_defer()
    assert z.predicted_peak() == 0.0


def test_forecaster_predicted_peak_leads_a_ramp():
    f = WorkloadForecaster(horizon=5)
    for t in range(400):
        f.observe(1000.0 + 5.0 * t)
    assert f.warmed_up
    # on a rising ramp the peak within the horizon exceeds the last
    # observation — that lead is what the proactive controller plans for
    assert f.predicted_peak() > f._last


# -- young/daly ----------------------------------------------------------------

def test_young_daly_matches_first_order():
    w = young_daly_interval(10.0, 86400.0, higher_order=False)
    assert abs(w - np.sqrt(2 * 10 * 86400)) < 1e-6


def test_young_daly_monotone_in_mtbf():
    a = young_daly_interval(5.0, 3600.0)
    b = young_daly_interval(5.0, 86400.0)
    assert b > a
