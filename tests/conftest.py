"""Pytest config: tests run on the default single CPU device (the dry-run
sets its 512 placeholder devices in its own process — never globally)."""
import os
import sys

# keep tests importable without `pip install -e .`
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
