"""Pytest config: tests run on the default single CPU device (the dry-run
sets its 512 placeholder devices in its own process — never globally)."""
import os
import sys

# keep tests importable without `pip install -e .`
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Pin the XLA CPU backend to a pre-FMA ISA BEFORE any test initializes a
# backend: LLVM contracts f64 mul-add chains into FMAs on wider ISAs, which
# breaks the device campaign's bit-exact parity with the NumPy engine
# (sim/device.py documents the finding).  Kernel tests are tolerance-based
# and unaffected.
if "--xla_cpu_max_isa" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_cpu_max_isa=AVX").strip()
