"""Model-layer numerics: chunked-flash XLA path vs full attention; rglru /
wkv jnp paths vs their kernel oracles; MoE grouping invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, MoEConfig, RecurrentConfig, RWKVConfig
from repro.models import layers as L


def test_flash_xla_matches_full_attention():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, hd = 2, 256, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    full = L.full_attention(q, k, v, causal=True)
    fl = L.flash_attention_xla(q, k, v, causal=True, chunk_q=64, chunk_kv=64)
    np.testing.assert_allclose(np.asarray(fl), np.asarray(full),
                               atol=2e-5, rtol=2e-5)


def test_flash_xla_window_matches_full():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, hd = 1, 256, 2, 32
    q, k, v = (jax.random.normal(kk, (B, S, H, hd)) for kk in ks)
    full = L.full_attention(q, k, v, causal=True, window=48)
    fl = L.flash_attention_xla(q, k, v, causal=True, window=48,
                               chunk_q=64, chunk_kv=64)
    np.testing.assert_allclose(np.asarray(fl), np.asarray(full), atol=2e-5)


def test_rglru_sequence_matches_kernel_ref():
    """The model's rglru_sequence recurrence == the kernel oracle recurrence
    given identical gates."""
    from repro.kernels.rglru.ref import rglru_ref
    cfg = ModelConfig(name="t", family="hybrid", num_layers=3, d_model=32,
                      num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=128,
                      recurrent=RecurrentConfig(lru_width=32),
                      dtype="float32")
    p = L.init_rglru_block(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32)) * 0.5
    y, (h_last, conv) = L.rglru_sequence(p, x, cfg, chunk=16)
    # recompute gates exactly as the layer does, then run the oracle scan
    dt = x.dtype
    u = x @ p["w_x"].astype(dt)
    cw = cfg.recurrent.conv1d_width
    u_pad = jnp.concatenate([jnp.zeros((2, cw - 1, 32), dt), u], axis=1)
    conv_w = p["conv_w"].astype(dt)
    uc = sum(u_pad[:, i:i + 64] * conv_w[i] for i in range(cw)) + p["conv_b"].astype(dt)
    a, b = L._rglru_gates(p, uc)
    h_ref = rglru_ref(a, b, jnp.zeros((2, 32)))
    gate = jax.nn.gelu(x @ p["w_gate"].astype(dt))
    y_ref = (h_ref.astype(dt) * gate) @ p["w_out"].astype(dt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h_ref[:, -1]),
                               atol=1e-4)


def test_rglru_decode_steps_match_sequence():
    cfg = ModelConfig(name="t", family="hybrid", num_layers=3, d_model=16,
                      num_heads=2, num_kv_heads=1, d_ff=32, vocab_size=128,
                      recurrent=RecurrentConfig(lru_width=16), dtype="float32")
    p = L.init_rglru_block(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 12, 16)) * 0.5
    y_seq, (h_seq, conv_seq) = L.rglru_sequence(p, x, cfg, chunk=4)
    h = jnp.zeros((1, 16), jnp.float32)
    conv = jnp.zeros((1, cfg.recurrent.conv1d_width - 1, 16), jnp.float32)
    ys = []
    for t in range(12):
        y_t, (h, conv) = L.rglru_decode_step(p, x[:, t:t + 1], cfg,
                                             h=h, conv_state=conv)
        ys.append(y_t)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_seq), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_seq), atol=1e-4)


def test_rwkv_time_mix_matches_kernel_ref():
    from repro.kernels.rwkv6.ref import wkv6_ref
    cfg = ModelConfig(name="t", family="ssm", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=128,
                      rwkv=RWKVConfig(head_size=16, decay_lora=8),
                      dtype="float32", norm_type="layernorm")
    p = L.init_rwkv_block(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 20, 32)) * 0.5
    y, (x_last, s_last) = L.rwkv_time_mix(p, x, cfg)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y)))
    np.testing.assert_allclose(np.asarray(x_last), np.asarray(x[:, -1]))


def test_moe_group_count_changes_only_capacity_drops():
    """With ample capacity, the grouped dispatch output is independent of
    the number of groups (the dp-local grouping is semantics-preserving)."""
    cfg = ModelConfig(name="t", family="moe", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=128,
                      moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                                    capacity_factor=8.0),
                      dtype="float32")
    p = L.init_moe(jax.random.PRNGKey(6), cfg)
    x = jax.random.normal(jax.random.PRNGKey(7), (4, 8, 32)) * 0.5

    class Ann(L.NullAnnotator):
        def __init__(self, g):
            self.moe_groups = g

    y1, aux1 = L.apply_moe(p, x, cfg, ann=Ann(1))
    y4, aux4 = L.apply_moe(p, x, cfg, ann=Ann(4))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), atol=1e-5)


def test_moe_capacity_drops_tokens():
    cfg = ModelConfig(name="t", family="moe", num_layers=2, d_model=16,
                      num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=128,
                      moe=MoEConfig(num_experts=2, top_k=2, d_ff_expert=32,
                                    capacity_factor=0.1),
                      dtype="float32")
    p = L.init_moe(jax.random.PRNGKey(8), cfg)
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 64, 16))
    y, _ = L.apply_moe(p, x, cfg)
    # with capacity_factor 0.1 most tokens drop -> many zero rows
    zero_rows = np.mean(np.all(np.asarray(y) == 0, axis=-1))
    assert zero_rows > 0.3


def test_mrope_sections_positional_structure():
    angles = L.rope_angles(jnp.stack([jnp.arange(8)[None] * 1,
                                      jnp.arange(8)[None] * 2,
                                      jnp.arange(8)[None] * 3]),
                           head_dim=16, theta=100.0,
                           mrope_sections=(3, 3, 2))
    assert angles.shape == (1, 8, 8)
    # section boundaries use different position streams
    assert not np.allclose(np.asarray(angles[0, :, 0]),
                           np.asarray(angles[0, :, 3]))


def test_cross_entropy_matches_naive():
    rng = jax.random.PRNGKey(10)
    logits = jax.random.normal(rng, (2, 8, 50))
    labels = jax.random.randint(rng, (2, 8), 0, 50)
    ours = L.cross_entropy(logits, labels)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    naive = jnp.mean(lse - gold)
    np.testing.assert_allclose(float(ours), float(naive), rtol=1e-6)
