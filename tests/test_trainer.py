"""Live resilient trainer + serving loop (real JAX on CPU, tiny model)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import OptimizerConfig
from repro.configs import get_smoke_config
from repro.data.stream import EventStream, constant_rate
from repro.models import zoo
from repro.runtime import ResilientTrainer, StreamServer, TrainerConfig


def _trainer(tmp_path, ci=5.0, ckpt_async=False):
    cfg = get_smoke_config("yi-6b")
    tcfg = TrainerConfig(batch=4, seq_len=16, ckpt_dir=str(tmp_path),
                         ckpt_interval_s=ci, ckpt_async=ckpt_async,
                         time_scale=20.0, detect_s=1.0, restart_s=1.0)
    stream = EventStream(schedule=constant_rate(500.0))
    stream.produce_until(0.0)
    return ResilientTrainer(cfg, tcfg, stream,
                            OptimizerConfig(total_steps=1000, lr=1e-3))


def test_trainer_runs_and_checkpoints(tmp_path):
    tr = _trainer(tmp_path)
    summary = tr.run(duration_s=40.0)
    assert summary["final_step"] > 3
    assert summary["checkpoints"] >= 1
    assert np.isfinite(summary["final_loss"])


def test_trainer_survives_injected_failure_and_restores(tmp_path):
    tr = _trainer(tmp_path, ci=4.0)
    tr.inject_failure_at(15.0)
    summary = tr.run(duration_s=60.0)
    assert summary["failures"] == 1
    assert summary["restores"] == 1
    assert summary["final_step"] > 3
    assert np.isfinite(summary["final_loss"])
    # restore rolled the step counter back to a checkpointed value then
    # progressed again: events must show restore step <= failure-time step
    ev = summary["events"]
    restore = next(e for e in ev if e["event"] == "restore")
    assert restore["step"] >= 0


def test_trainer_hot_ci_reconfigure(tmp_path):
    tr = _trainer(tmp_path, ci=50.0)
    tr.set_ci(2.0)
    summary = tr.run(duration_s=30.0)
    assert summary["checkpoints"] >= 2     # new cadence took effect
    assert any(e["event"] == "reconfigure" for e in summary["events"])


def test_trainer_loss_decreases_over_training(tmp_path):
    tr = _trainer(tmp_path, ci=1e9)        # no checkpoint interference
    tr.run(duration_s=120.0)
    losses = tr.losses
    assert len(losses) > 10
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_stream_server_serves_batch():
    cfg = get_smoke_config("yi-6b")
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    srv = StreamServer(cfg, params, max_batch=4)
    from repro.runtime.server import ServeRequest
    rng = np.random.default_rng(0)
    reqs = [ServeRequest(rid=i, prompt=rng.integers(0, cfg.vocab_size, 16,
                                                    dtype=np.int32),
                         max_new_tokens=4) for i in range(3)]
    out = srv.serve_batch(reqs)
    assert set(out) == {0, 1, 2}
    for toks in out.values():
        assert toks.shape == (4,)
        assert toks.min() >= 0


def test_stream_server_decode_positions_advance():
    """Regression: every decode step must write a DISTINCT cache slot,
    advancing from the prompt length — the old loop pinned pos at S-1, so
    each step stomped one slot (out-of-range scatters are silently
    dropped) and rotated every query to the same RoPE angle."""
    cfg = get_smoke_config("yi-6b")
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    srv = StreamServer(cfg, params, max_batch=2, max_seq=64)
    from repro.runtime.server import ServeRequest
    rng = np.random.default_rng(1)
    S, new = 16, 4
    prompts = [rng.integers(0, cfg.vocab_size, S, dtype=np.int32)
               for _ in range(2)]
    out = srv.serve_batch([ServeRequest(rid=i, prompt=p, max_new_tokens=new)
                           for i, p in enumerate(prompts)])
    assert srv.last_decode_positions == list(range(S, S + new - 1))
    # oracle: greedy decode with correctly-advancing positions continues
    # exactly as a fresh prefill over the extended prompt would (decode /
    # prefill argmax parity is asserted in test_arch_smoke)
    prefill = jax.jit(zoo.make_prefill_step(cfg))
    for i, p in enumerate(prompts):
        toks = out[i]
        ext = jnp.asarray(np.concatenate([p, toks[:-1]]))[None]
        next_ref, _ = prefill(params, {"tokens": ext})
        assert int(next_ref[0]) == int(toks[-1])
    # prompt + generation must FIT the cache; overflow is a loud error
    with pytest.raises(AssertionError, match="max_seq"):
        srv.serve_batch([ServeRequest(
            rid=9, prompt=rng.integers(0, cfg.vocab_size, 62,
                                       dtype=np.int32),
            max_new_tokens=8)])
