"""The peer-replication plane: ring placement, bounded-retry pushes,
quorum commit, host kills, degraded partial restore, and the derived
survival rule the cost model prices from (PR 7 tentpole)."""
import os

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.multilevel import (LEVEL_COVERAGE, allowed_levels,
                                         derived_coverage, level_survives)
from repro.checkpoint.replication import (PeerReplicatedStore,
                                          ReplicationError,
                                          retry_with_backoff, ring_peers)
from repro.checkpoint.store import CheckpointStore
from repro.config import CheckpointPlan
from repro.sim import SimCostModel


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": rng.standard_normal((64, 32)).astype(np.float32),
            "v": rng.standard_normal((512,)).astype(np.float32),
            "m": rng.standard_normal((100,)).astype(np.float64),
            "step": np.asarray(42, np.int64)}


def _same(a, b):
    return all(np.array_equal(np.asarray(a[k]), np.asarray(b[k])) for k in a)


# ---------------------------------------------------------------------------
# ring placement + retry primitives
# ---------------------------------------------------------------------------

def test_ring_peers_wraps_and_clamps():
    assert ring_peers(0, 4, 1) == (1,)
    assert ring_peers(3, 4, 2) == (0, 1)       # wraps mod H
    assert ring_peers(2, 4, 9) == (3, 0, 1)    # clamped to H-1 distinct peers
    assert ring_peers(0, 1, 3) == ()           # no peers to push to
    assert ring_peers(5, 8, 0) == ()


def test_retry_with_backoff_bounded_and_jittered():
    sleeps = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    out = retry_with_backoff(flaky, attempts=4, base_s=0.1, factor=2.0,
                             sleep=sleeps.append)
    assert out == "ok" and calls["n"] == 3
    assert len(sleeps) == 2
    # exponential envelope with jitter in [1, 1.5): 0.1*2^i * [1, 1.5)
    assert 0.1 <= sleeps[0] < 0.15 and 0.2 <= sleeps[1] < 0.3

    def always():
        raise OSError("dead disk")

    with pytest.raises(OSError, match="dead disk"):
        retry_with_backoff(always, attempts=3, sleep=lambda s: None)
    # non-OSError propagates immediately, no retry
    with pytest.raises(ValueError):
        retry_with_backoff(lambda: (_ for _ in ()).throw(ValueError("x")),
                           attempts=5, sleep=lambda s: None)


def test_store_write_retries_through_flaky_filesystem(tmp_path):
    """Satellite: a transient IO error on a (remote-level) store write is
    retried with backoff instead of failing the save."""
    fails = {"n": 0}

    def flaky_fs(path):
        # deterministic under the concurrent shard writers: only shard 0's
        # writer (whose retries are sequential) sees the transient errors
        if path.endswith("shard_00000.npz") and fails["n"] < 2:
            fails["n"] += 1
            raise OSError("EIO: transient")

    store = CheckpointStore(str(tmp_path / "remote"), num_shards=4,
                            fault_hook=flaky_fs, write_backoff_s=0.0)
    state = _state()
    store.save(7, state)
    assert store.write_retries == 2
    assert store.stats()["write_retries"] == 2
    got, _ = store.restore(state, 7)
    assert _same(got, state)

    # a PERSISTENT error still propagates after bounded retry, and the
    # half-written checkpoint stays invisible
    dead = CheckpointStore(str(tmp_path / "dead"), num_shards=2,
                           fault_hook=lambda p: (_ for _ in ()).throw(
                               OSError("gone")),
                           write_backoff_s=0.0, write_attempts=2)
    with pytest.raises(OSError):
        dead.save(8, state)
    assert dead.newest() is None


# ---------------------------------------------------------------------------
# replicated store: push/quorum/kill/restore
# ---------------------------------------------------------------------------

def test_replicated_save_pushes_ring_replicas(tmp_path):
    store = PeerReplicatedStore(str(tmp_path), num_shards=4,
                                replication_factor=1, sleep=lambda s: None)
    store.save(3, _state())
    files = sorted(os.listdir(tmp_path / "step_0000000003"))
    # every shard j has exactly one replica, on ring peer (j+1) % 4
    for j in range(4):
        assert f"replica_h{(j + 1) % 4:03d}_shard_{j:05d}.npz" in files
    assert store.replica_stats.acks == 4
    assert store.replica_stats.replica_bytes > 0
    m = store._valid("step_0000000003")
    assert m["placement"]["owners"]["shard_00002.npz"] == 2
    assert len(m["replicas"]) == 4


def test_quorum_failure_leaves_no_manifest(tmp_path):
    """A push that dies after bounded retry fails the quorum, the save
    raises, and NOTHING becomes visible — the commit-marker invariant."""
    def kill_replicas(path):
        if "replica_" in os.path.basename(path):
            raise OSError("peer unreachable")

    store = PeerReplicatedStore(str(tmp_path), num_shards=4,
                                replication_factor=1,
                                fault_hook=kill_replicas,
                                push_attempts=2, push_backoff_s=0.0,
                                sleep=lambda s: None)
    with pytest.raises(ReplicationError, match="quorum"):
        store.save(5, _state())
    assert store.newest() is None
    assert store.replica_stats.push_failures == 4   # counted on the main thread
    assert store.replica_stats.push_retries >= 1    # backoff was exercised


def test_kill_host_then_degraded_partial_restore(tmp_path):
    state = _state()
    store = PeerReplicatedStore(str(tmp_path), num_shards=4,
                                replication_factor=1, sleep=lambda s: None)
    store.save(9, state)
    full = store.total_bytes(9)
    removed = store.kill_host(1)
    # host 1 loses its primary shard AND the replica it held for host 0
    assert any("shard_00001.npz" in r and "replica" not in r
               for r in removed)
    assert any(r.endswith("replica_h001_shard_00000.npz") for r in removed)
    assert store.newest() == 9          # replicas keep the step valid
    got, _ = store.restore(state)
    assert _same(got, state)
    lr = store.last_restore
    assert lr["degraded"] and lr["shards_from_peer"] == 1
    assert 0 < lr["restored_bytes"] < full


def test_peer_loss_falls_back_per_shard_to_remote(tmp_path):
    state = _state(3)
    local = PeerReplicatedStore(str(tmp_path / "local"), num_shards=4,
                                replication_factor=1, sleep=lambda s: None)
    remote = CheckpointStore(str(tmp_path / "remote"), num_shards=2)
    local.save(11, state)
    remote.save(11, state)
    # k=1 worst case: a host and the peer holding its replica both die
    local.kill_host(2)
    local.kill_host(3)
    assert local.newest() is None                         # not locally whole
    assert local.newest_restorable(remote.list_steps()) == 11
    got, _ = local.restore(state, step=11, shard_fallback=remote.read_leaves)
    assert _same(got, state)
    lr = local.last_restore
    assert lr["shards_from_remote"] >= 1 and lr["degraded"]
    # without a fallback the same restore must refuse, not corrupt
    with pytest.raises(FileNotFoundError):
        local.restore(state, step=11)


def test_read_leaves_loads_only_owning_shards(tmp_path):
    state = _state(4)
    store = CheckpointStore(str(tmp_path), num_shards=4)
    store.save(2, state)
    m = store._valid("step_0000000002")
    name = "w"
    got = store.read_leaves(2, [name])
    assert np.array_equal(got[name], state[name])
    # only leaves sharing the shard ride along, never the whole state
    shard_of_w = m["assign"][name]
    expect = {n for n, j in m["assign"].items() if j == shard_of_w}
    assert set(got) == expect
    with pytest.raises(KeyError):
        store.read_leaves(2, ["nope"])


# ---------------------------------------------------------------------------
# derived survival + cost-model pricing
# ---------------------------------------------------------------------------

def test_survival_derived_from_replication():
    assert derived_coverage(1) == LEVEL_COVERAGE
    assert derived_coverage(0)["node"] == "remote"
    assert level_survives("local", "node", 1)
    assert not level_survives("local", "node", 0)
    assert not level_survives("local", "cluster", 99)   # k can't save a cluster
    assert allowed_levels("node", 0) == ("remote",)
    assert allowed_levels("node", 1) == ("local", "remote")
    with pytest.raises(ValueError, match="known kinds"):
        allowed_levels("rack", 1)
    with pytest.raises(ValueError, match="unknown level"):
        level_survives("tape", "node")


def test_costmodel_prices_replication_dimension():
    cost = SimCostModel(state_bytes=1e9, replica_push_factor=0.1)
    rep1 = CheckpointPlan(levels=("local", "remote"), replication_factor=1)
    rep0 = CheckpointPlan(levels=("local", "remote"), replication_factor=0)
    rep2 = CheckpointPlan(levels=("local", "remote"), replication_factor=2)
    # survival: derived, not hard-coded
    assert cost.surviving_levels(rep1, "node") == ("local", "remote")
    assert cost.surviving_levels(rep0, "node") == ("remote",)
    # wipes: an un-replicated plan loses local disk to a node failure
    assert cost.wiped_levels(rep0, "node") == ("memory", "local")
    assert cost.wiped_levels(rep1, "node") == ("memory",)
    assert cost.wiped_levels(rep1, "cluster") == ("memory", "local")
    # replica traffic scales with k; rep0 pays none
    assert cost.avg_replica_bytes(rep0) == 0.0
    assert cost.avg_replica_bytes(rep2) == \
        pytest.approx(2 * cost.avg_replica_bytes(rep1))
    # write duration: each replica push adds replica_push_factor x payload
    base = cost.write_duration("full", "local")
    assert cost.write_duration("full", "local", replicas=2) == \
        pytest.approx(base * 1.2)
    # downtime: replicas buy the fast level-2 node restore
    assert cost.plan_downtime_s(rep1, "node") < \
        cost.plan_downtime_s(rep0, "node")
    # degraded restore pricing is reachable and scales with the factor
    slow = SimCostModel(replica_restore_factor=1.5)
    assert slow.restore_duration_for(rep1, "node", "local") == \
        pytest.approx(1.5 * slow.restore_duration("local"))
    assert slow.restore_duration_for(rep0, "node", "remote") == \
        pytest.approx(slow.restore_duration("remote"))


def test_default_variants_carry_replication_dimension():
    from repro.core.ci_optimizer import default_plan_variants

    variants = default_plan_variants(SimCostModel(state_bytes=1e9),
                                     ci_ref=60.0)
    reps = {p.replication_factor for p in variants}
    assert {0, 1, 2} <= reps
    # rep appears in the plan tag only when it leaves the default
    assert any(p.name.endswith("rep0") for p in variants)
    assert any(p.name.endswith("rep2") for p in variants)


# ---------------------------------------------------------------------------
# manager-level drills (the acceptance path end to end)
# ---------------------------------------------------------------------------

def test_manager_node_failure_recovers_from_peers_bit_exact(tmp_path):
    state = _state(5)
    plan = CheckpointPlan(levels=("local", "remote"), remote_every=1,
                         num_shards=4, replication_factor=1)
    mgr = CheckpointManager(str(tmp_path), plan)
    mgr.save(50, state, 1.0)
    mgr.on_failure("node", host=0)
    rep = mgr.restore(state, "node")
    assert rep.level == "local" and rep.degraded
    assert 0 < rep.restored_bytes < mgr.stores["local"].total_bytes(50)
    assert _same(rep.state, state)


def test_manager_rep0_degrades_to_remote(tmp_path):
    state = _state(6)
    plan = CheckpointPlan(levels=("local", "remote"), remote_every=1,
                         num_shards=4, replication_factor=0)
    mgr = CheckpointManager(str(tmp_path), plan)
    assert not isinstance(mgr.stores["local"], PeerReplicatedStore)
    mgr.save(50, state, 1.0)
    mgr.on_failure("node", host=0)
    rep = mgr.restore(state, "node")
    assert rep.level == "remote" and not rep.degraded
    assert _same(rep.state, state)


def test_manager_untargeted_node_failure_keeps_local_disk(tmp_path):
    """host=None keeps the legacy semantics: the process dies, the node's
    disk survives, the restore is a healthy local read."""
    state = _state(8)
    plan = CheckpointPlan(levels=("local",), num_shards=4,
                         replication_factor=1)
    mgr = CheckpointManager(str(tmp_path), plan)
    mgr.save(50, state, 1.0)
    mgr.on_failure("node")
    rep = mgr.restore(state, "node")
    assert rep.level == "local" and not rep.degraded
    assert rep.restored_bytes == 0
    assert _same(rep.state, state)
