"""Roofline HLO walker: trip counts, dot flops, collective accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import (HloModule, analyze_hlo_text, model_flops,
                            roofline_terms)
from repro.config import TRAIN_4K, DECODE_32K
from repro.configs import get_config

_TOY_HLO = """
HloModule toy

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16] get-tuple-element(%p), index=1
  %w = f32[16,16] constant({...})
  %dot.1 = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ni, %dot.1)
}

%cond (p2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(7)
  ROOT %cmp = pred[] compare(%i2, %n), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%zero, %a)
  %w2 = while((s32[], f32[8,16]) %init), condition=%cond, body=%body
  %ar = f32[8,16] get-tuple-element(%w2), index=1
  ROOT %red = f32[8,16] all-reduce(%ar), replica_groups={}, to_apply=%cond
}
"""


def test_walker_trip_count_multiplies_body_flops():
    mod = HloModule(_TOY_HLO)
    costs = mod.cost()
    # dot: 2*8*16*16 = 4096 flops, x7 trips
    assert costs.flops == pytest.approx(7 * 4096)
    # all-reduce operand: 8*16*4 bytes
    assert costs.collective_bytes == pytest.approx(8 * 16 * 4)
    assert costs.by_type == {"all-reduce": 8 * 16 * 4}


def test_walker_on_real_scanned_program():
    """Compile a scanned matmul chain and check the walker ~ analytic flops."""
    L, n = 5, 64
    ws = jnp.ones((L, n, n), jnp.float32)

    def f(x, ws):
        def body(x, w):
            return x @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    hlo = jax.jit(f).lower(jnp.ones((n, n)), ws).compile().as_text()
    costs = analyze_hlo_text(hlo)
    analytic = L * 2 * n ** 3
    assert costs.flops == pytest.approx(analytic, rel=0.01)


def test_roofline_terms_dominance():
    from repro.roofline import HloCosts, PEAK_FLOPS, HBM_BW
    c = HloCosts(flops=PEAK_FLOPS, bytes=HBM_BW / 10, collective_bytes=0)
    t = roofline_terms(c)
    assert t["dominant"] == "compute"
    assert t["t_compute_s"] == pytest.approx(1.0)
    assert t["roofline_fraction"] == pytest.approx(1.0)


def test_model_flops_train_vs_decode():
    cfg = get_config("yi-6b")
    train = model_flops(cfg, TRAIN_4K)
    decode = model_flops(cfg, DECODE_32K)
    assert train == pytest.approx(6 * cfg.param_count() * 256 * 4096)
    assert decode == pytest.approx(2 * cfg.param_count() * 128)


def test_model_flops_moe_uses_active_params():
    cfg = get_config("olmoe-1b-7b")
    assert model_flops(cfg, TRAIN_4K) == pytest.approx(
        6 * cfg.active_param_count() * 256 * 4096)
