"""Fleet supervision: registry transfer, admission control, pooled
profiling, the multiplexed Phase-3 tick, and the bounded metrics plane."""
import numpy as np
import pytest

from repro.config import KhaosConfig, replace
from repro.core.qos_models import QoSModel, demo_prior_models
from repro.core.runtime import KhaosRuntime, PhaseError
from repro.data.stream import constant_rate, record_workload
from repro.fleet import (DivergenceWatchdog, FleetJobSpec, FleetSupervisor,
                         JobFingerprint, QoSModelRegistry, decide_admission,
                         fingerprint)
from repro.metrics import MetricsStore, TimeSeries
from repro.sim import BatchedDeployment, SimCostModel


def _cost(**kw):
    kw.setdefault("capacity_eps", 2600.0)
    kw.setdefault("ckpt_duration_s", 1.0)
    kw.setdefault("state_bytes", 1e9)
    return SimCostModel(**kw)


def _cfg(**kw):
    kw.setdefault("latency_constraint", 1.5)
    kw.setdefault("recovery_constraint", 240.0)
    kw.setdefault("optimization_period", 30.0)
    kw.setdefault("ci_min", 10.0)
    kw.setdefault("ci_max", 120.0)
    kw.setdefault("num_failure_points", 2)
    kw.setdefault("num_configs", 2)
    kw.setdefault("record_seconds", 400.0)
    kw.setdefault("reconfig_cooldown", 60.0)
    return KhaosConfig(**kw)


def _spec(name, rate=1200.0, **kw):
    kw.setdefault("cost", _cost())
    kw.setdefault("cfg", _cfg())
    kw.setdefault("schedule", constant_rate(rate))
    kw.setdefault("horizon_s", 300.0)
    kw.setdefault("profile_max_recovery_s", 600.0)
    return FleetJobSpec(name, **kw)


# ---------------------------------------------------------------------------
# fingerprints + registry
# ---------------------------------------------------------------------------

def test_fingerprint_matches_near_twin_and_misses_different_job():
    cfg = _cfg()
    rec_a = record_workload(constant_rate(1200.0), 400.0, seed=0)
    rec_b = record_workload(constant_rate(1200.0), 400.0, seed=7)
    fp_a = fingerprint(cfg, rec_a, state_bytes=1e9)
    fp_b = fingerprint(cfg, rec_b, state_bytes=1e9)
    assert fp_a.key() == fp_b.key()       # twin workloads collide (hit)
    # 4x the state -> different write/restore economics -> miss
    assert fingerprint(cfg, rec_a, 4e9).key() != fp_a.key()
    # 4x the rate envelope -> miss
    rec_hot = record_workload(constant_rate(4800.0), 400.0, seed=0)
    assert fingerprint(cfg, rec_hot, 1e9).key() != fp_a.key()
    # different CI search window -> miss
    assert fingerprint(replace(cfg, ci_max=300.0), rec_a, 1e9).key() \
        != fp_a.key()


def test_registry_roundtrip(tmp_path):
    m_l, m_r = demo_prior_models()
    cfg = _cfg()
    rec = record_workload(constant_rate(1200.0), 400.0, seed=0)
    fp = fingerprint(cfg, rec, 1e9)
    reg = QoSModelRegistry()
    assert reg.lookup(fp) is None
    reg.put(fp, m_l, m_r, "donor-job")
    path = str(tmp_path / "registry.json")
    reg.save(path)
    back = QoSModelRegistry.load(path)
    entry = back.lookup(fp)
    assert entry is not None and entry.source_job == "donor-job"
    ci = np.linspace(10, 60, 7)
    tr = np.linspace(200, 900, 7)
    np.testing.assert_allclose(entry.m_l.predict(ci, tr),
                               m_l.predict(ci, tr), rtol=1e-12)
    np.testing.assert_allclose(entry.m_r.predict(ci, tr),
                               m_r.predict(ci, tr), rtol=1e-12)


def test_divergence_watchdog_fires_once_per_episode():
    wd = DivergenceWatchdog(rel_err_threshold=0.5, patience=2)
    assert not wd.observe(1.0, 1.0)        # accurate
    assert not wd.observe(2.0, 1.0)        # bad x1
    assert wd.observe(2.0, 1.0)            # bad x2 -> fires
    assert not wd.observe(2.0, 1.0)        # same episode: no refire
    assert not wd.observe(1.0, 1.0)        # recovers
    assert not wd.observe(2.0, 1.0)
    assert wd.observe(2.0, 1.0)            # new episode fires again


# ---------------------------------------------------------------------------
# adopt_models phase legality
# ---------------------------------------------------------------------------

def test_adopt_models_requires_phase1_and_logs_transfer():
    m_l, m_r = demo_prior_models()
    rt = KhaosRuntime(_cfg())
    with pytest.raises(PhaseError):
        rt.adopt_models(m_l, m_r)          # Phase 1 has not run
    rec = record_workload(constant_rate(1200.0), 400.0, seed=0)
    rt.record_steady_state(rec)
    rt.adopt_models(m_l, m_r, source="neighbor")
    assert rt.phase == "profiled" and rt.transferred
    ev = rt.phase_log[-1]
    assert ev.phase == "profiled" and ev.info["transferred"] \
        and ev.info["source"] == "neighbor"


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_admission_rejects_queues_and_admits():
    cost, cfg = _cost(), _cfg()
    rec = record_workload(constant_rate(1200.0), 400.0, seed=0)
    ok = decide_admission("j", cost, rec, cfg, residual_eps=8000.0)
    assert ok.action == "admit" and ok.admitted
    over = decide_admission("j", cost, rec, cfg, residual_eps=500.0)
    assert over.action == "reject" and not over.admitted
    q = decide_admission("j", cost, rec, cfg, residual_eps=500.0,
                         queueable=True)
    assert q.action == "queue" and not q.admitted


def test_whatif_catches_recovery_infeasible_residual():
    """A job that fits the budget at steady state but cannot drain its
    post-failure backlog at the residual capacity is still rejected —
    the what-if campaign, not the reservation arithmetic, catches it."""
    cost, cfg = _cost(), _cfg(recovery_constraint=60.0)
    rec = record_workload(constant_rate(1200.0), 400.0, seed=0)
    # residual barely above the reservation: replay drains too slowly
    d = decide_admission("j", cost, rec, cfg,
                         residual_eps=1500.0, headroom=0.0)
    assert d.action == "reject"
    assert "what-if" in d.reason
    assert d.whatif_recovery_s > cfg.recovery_constraint


def test_supervisor_queue_retry_after_capacity_frees():
    sup = FleetSupervisor(fleet_capacity_eps=2600.0)
    d1 = sup.submit(_spec("first", rate=1200.0))
    assert d1.admitted
    d2 = sup.submit(_spec("waiting", rate=1200.0, queueable=True))
    assert d2.action == "queue"
    assert sup.jobs["waiting"].status == "queued"
    # first job finishes -> its reservation is released -> retry admits
    sup.jobs["first"].status = "done"
    sup.reserved_eps -= sup.jobs["first"].admission.reserved_eps
    out = sup.retry_queued()
    assert [d.action for d in out] == ["admit"]
    assert sup.jobs["waiting"].status == "admitted"


# ---------------------------------------------------------------------------
# pooled profiling
# ---------------------------------------------------------------------------

def test_pooled_profiling_matches_solo_deployment():
    """A job profiled as a slice of the POOLED multi-job campaign gets
    bit-identical (L, R) matrices to profiling alone through its own
    BatchedDeployment — lanes are independent, pooling is free."""
    sup = FleetSupervisor(fleet_capacity_eps=10_000.0)
    sup.submit(_spec("a", rate=1200.0, seed=0))
    sup.submit(_spec("b", rate=1400.0, seed=1))
    sup.run_profiling_pooled()
    job = sup.jobs["a"]
    rt_solo = KhaosRuntime(_cfg())
    rt_solo.record_steady_state(job.recording)
    rt_solo.run_profiling(
        BatchedDeployment(job.spec.cost, job.recording,
                          warmup_s=job.spec.profile_warmup_s,
                          max_recovery_s=job.spec.profile_max_recovery_s),
        ci_values=rt_solo.default_ci_grid(),
        margin=job.spec.cfg.profile_margin_seconds)
    np.testing.assert_array_equal(job.runtime.profile.latencies,
                                  rt_solo.profile.latencies)
    np.testing.assert_array_equal(job.runtime.profile.recoveries,
                                  rt_solo.profile.recoveries)
    # both jobs walked the legal phase order through the shared sweep
    for name in ("a", "b"):
        assert sup.jobs[name].runtime.phase_sequence() == \
            ["steady_state", "profiled"]
    assert len(sup.registry) >= 1


# ---------------------------------------------------------------------------
# transfer fast path + divergence fallback (the tentpole loop)
# ---------------------------------------------------------------------------

def _fleet_with_transfer(divergence_threshold, patience=1):
    sup = FleetSupervisor(fleet_capacity_eps=10_000.0,
                          divergence_threshold=divergence_threshold,
                          divergence_patience=patience)
    cfg = _cfg(num_failure_points=3, num_configs=3)
    assert sup.submit(_spec("donor", rate=1200.0, seed=0,
                            cfg=cfg)).action == "admit"
    sup.run_profiling_pooled()
    d = sup.submit(_spec("twin", rate=1200.0, seed=3, cfg=cfg))
    assert d.action == "admit_transfer"
    return sup


def test_transfer_skips_phase2_with_less_lane_time():
    sup = _fleet_with_transfer(divergence_threshold=1e9)
    donor, twin = sup.jobs["donor"], sup.jobs["twin"]
    # the machine walked steady_state -> profiled WITHOUT a campaign
    assert twin.runtime.phase == "profiled" and twin.runtime.transferred
    assert twin.transfer_source == "donor"
    # cold z x m grid (9 lanes) vs ONE validation-probe lane
    assert donor.profiling_lane_ticks >= 5 * twin.profiling_lane_ticks
    sup.start()
    sup.run(300.0, chunk_s=30.0)
    assert twin.runtime.phase == "optimizing"
    assert twin.reprofiles == 0            # watchdog disabled: no fallback


def test_transfer_divergence_triggers_reprofile_reentry():
    sup = _fleet_with_transfer(divergence_threshold=1e-9, patience=1)
    twin = sup.jobs["twin"]
    sup.start()
    sup.run(300.0, chunk_s=30.0)
    # the watchdog tripped: a REAL Phase-2 re-entry ran mid-supervision
    assert twin.reprofiles == 1 and not twin.transferred
    seq = twin.runtime.phase_sequence()
    i = seq.index("reprofile")
    # the detour is logged, then the machine re-walks the legal order
    # (phase snaps back to steady_state in place, so the next logged
    # events are the re-fit and the re-entry)
    assert seq[i:i + 3] == ["reprofile", "profiled", "optimizing"]
    # the re-fitted models are the job's own now, and the registry healed
    assert twin.runtime.transferred      # transfer HAPPENED historically
    entry = sup.registry.lookup(twin.fp)
    assert entry.source_job == "twin"
    assert twin.watchdog is None         # disarmed after self-fit


# ---------------------------------------------------------------------------
# the multiplexed tick: shared campaign, shared decision log
# ---------------------------------------------------------------------------

def test_supervisor_multiplexes_substrates_with_shared_decision_log():
    sup = FleetSupervisor(fleet_capacity_eps=16_000.0)
    for i in range(3):
        assert sup.submit(_spec(f"lane{i}", rate=1100.0 + 100 * i,
                                seed=i)).admitted
    assert sup.submit(_spec("scalar0", rate=1200.0, seed=9,
                            substrate="scalar")).admitted
    sup.run_profiling_pooled()
    sup.start()
    status = sup.run(300.0, chunk_s=30.0)
    # ONE shared campaign carries every lane job
    assert status["shared_campaigns"] == 1
    camp = sup.jobs["lane0"].campaign
    assert camp is sup.jobs["lane1"].campaign is sup.jobs["lane2"].campaign
    assert {sup.jobs[f"lane{i}"].lane for i in range(3)} == {0, 1, 2}
    # every job reached Phase 3 through its own machine
    for n, j in sup.jobs.items():
        assert j.runtime.phase == "optimizing", n
    # the shared decision log saw every job's controller, labeled
    labels = {label for label, _d in sup.decision_log}
    assert labels == {"lane0", "lane1", "lane2", "scalar0"}
    for label, d in sup.decision_log:
        assert d.kind in ("none", "defer", "reconfigure", "proactive",
                          "infeasible", "cooldown", "unhealthy")
    # per-job and per-fleet series landed in the monitor plane
    for n in ("lane0", "scalar0"):
        assert len(sup.metrics.series(f"{n}/latency")) > 0
    assert len(sup.metrics.series("fleet/jobs_optimizing")) > 0
    assert sup.qos_violations("lane0")["qos_violation_s"] >= 0.0


# ---------------------------------------------------------------------------
# bounded metrics plane
# ---------------------------------------------------------------------------

def test_bounded_timeseries_holds_memory_flat():
    ts = TimeSeries("x", maxlen=64, max_rollups=8)
    n = 20_000
    for i in range(n):
        ts.append(float(i), float(i % 100))
    # raw buffer and rollup list are both bounded -> flat memory
    assert len(ts.times) <= 64
    assert len(ts.rollups) <= 8
    # lifetime aggregates still see every sample
    assert ts.lifetime_count() == n
    ref = np.arange(n) % 100
    assert abs(ts.lifetime_mean() - ref.mean()) < 1.0
    assert ts.lifetime_max() == ref.max()
    # recent-window queries stay exact over the raw tail
    t, v = ts.window(n - 10, n)
    np.testing.assert_array_equal(v, ref[-10:])


def test_bounded_store_vs_unbounded_reference():
    bounded = MetricsStore(maxlen=32)
    exact = MetricsStore()
    rng = np.random.default_rng(0)
    vals = rng.uniform(0, 10, 5000)
    for i, v in enumerate(vals):
        bounded.record("m", float(i), float(v))
        exact.record("m", float(i), float(v))
    b, e = bounded.series("m"), exact.series("m")
    assert len(b.times) <= 32 and len(e.times) == 5000
    assert b.lifetime_count() == e.lifetime_count()
    assert abs(b.lifetime_mean() - np.mean(vals)) < 1e-9
    assert b.lifetime_max() == np.max(vals)
    # non-monotonic appends still rejected in bounded mode
    with pytest.raises(ValueError):
        b.append(0.0, 1.0)


def test_rollup_merge_preserves_aggregates():
    from repro.metrics import Rollup
    a = Rollup(0.0, 9.0, 10, 2.0, 1.0, 5.0)
    b = Rollup(10.0, 19.0, 30, 4.0, 0.5, 9.0)
    m = a.merge(b)
    assert m.count == 40
    assert abs(m.mean - (2.0 * 10 + 4.0 * 30) / 40) < 1e-12
    assert m.vmin == 0.5 and m.vmax == 9.0
    assert m.t_start == 0.0 and m.t_end == 19.0


def test_fingerprint_key_format_is_stable():
    """Persisted registries (QoSModelRegistry.save) are keyed by this
    string — a format change silently orphans every saved surface on the
    next fleet restart, so the format is pinned as a literal."""
    fp = JobFingerprint(state_bytes_log2=30, rate_mean_bin=10,
                        rate_peak_bin=11, ci_window=(10.0, 120.0),
                        num_configs=12)
    assert fp.key() == "sb30-rm10-rp11-ci10_120-z12"
    # and a real fingerprint survives the JSON round trip key-intact
    cfg = _cfg()
    rec = record_workload(constant_rate(1200.0), 400.0, seed=0)
    real = fingerprint(cfg, rec, 1e9)
    m_l, m_r = demo_prior_models()
    reg = QoSModelRegistry()
    reg.put(real, m_l, m_r, "donor")
    back = QoSModelRegistry.from_dict(reg.to_dict())
    entry = back.lookup(real)
    assert entry is not None and entry.fp.key() == real.key()


def test_registry_save_is_restart_stable(tmp_path):
    """save -> load -> save must be byte-identical (a fleet restarting in
    a loop never rewrites its registry), and reloaded surfaces must
    predict bit-exactly, not just approximately."""
    m_l, m_r = demo_prior_models()
    cfg = _cfg()
    rec = record_workload(constant_rate(1200.0), 400.0, seed=0)
    reg = QoSModelRegistry()
    reg.put(fingerprint(cfg, rec, 1e9), m_l, m_r, "donor")
    p1, p2 = str(tmp_path / "r1.json"), str(tmp_path / "r2.json")
    reg.save(p1)
    back = QoSModelRegistry.load(p1)
    back.save(p2)
    with open(p1, "rb") as a, open(p2, "rb") as b:
        assert a.read() == b.read()
    ci = np.linspace(10, 60, 7)
    tr = np.linspace(200, 900, 7)
    entry = back.lookup(fingerprint(cfg, rec, 1e9))
    np.testing.assert_array_equal(entry.m_l.predict(ci, tr),
                                  m_l.predict(ci, tr))
    np.testing.assert_array_equal(entry.m_r.predict(ci, tr),
                                  m_r.predict(ci, tr))
