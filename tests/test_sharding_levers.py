"""Sharding levers added during §Perf: SP, moe_megatron, controller gating."""
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config import KhaosConfig, ShardingConfig
from repro.configs import get_config
from repro.core import KhaosController, QoSModel
from repro.launch.mesh import make_abstract_mesh
from repro.sharding import ShardingRules


def _rules(arch="yi-6b", multi=False, **scfg):
    mesh = make_abstract_mesh(
        (2, 16, 16) if multi else (16, 16),
        ("pod", "data", "model") if multi else ("data", "model"))
    return ShardingRules(get_config(arch), mesh, ShardingConfig(**scfg))


def test_sp_shards_hidden_seq_dim():
    r = _rules(seq_shard_hidden=True)
    assert r.act_spec("hidden", (256, 4096, 4096)) == P("data", "model", None)
    # long_500k decode: seq dim 1 not divisible -> falls back cleanly
    assert r.act_spec("hidden", (1, 1, 2560)) == P(None, None, None)


def test_sp_off_by_default():
    r = _rules()
    assert r.act_spec("hidden", (256, 4096, 4096)) == P("data", None, None)


def test_moe_megatron_expert_ffn_sharding():
    r = _rules("grok-1-314b", fsdp_min_params=0, moe_megatron=True)
    up = r.param_spec("layers/moe/w_up", (64, 8, 6144, 32768))
    down = r.param_spec("layers/moe/w_down", (64, 8, 32768, 6144))
    assert up == P(None, None, None, ("data", "model"))
    assert down == P(None, None, ("data", "model"), None)


def test_moe_megatron_ignored_when_experts_divide():
    # olmoe: 64 experts divide tp=16 -> real EP wins over megatron fallback
    r = _rules("olmoe-1b-7b", fsdp=False, moe_megatron=True)
    up = r.param_spec("layers/moe/w_up", (16, 64, 2048, 1024))
    assert up == P(None, "model", None, None)


def test_controller_skips_unhealthy_job():
    rng = np.random.default_rng(0)
    ci = rng.uniform(10, 120, 40)
    tr = rng.uniform(500, 2000, 40)
    ctl = KhaosController(
        cfg=KhaosConfig(optimization_period=1.0),
        m_l=QoSModel().fit(ci, tr, 0.5 + 1 / ci),
        m_r=QoSModel().fit(ci, tr, 50 + ci))

    class Job:
        t = 100.0
        def now(self): return self.t
        def current_ci(self): return 60.0
        def avg_latency(self, w): return 50.0      # catastrophic (catch-up)
        def avg_throughput(self, w): return 1000.0
        def healthy(self): return False
        def reconfigure(self, ci): raise AssertionError("must not reconfigure")

    d = ctl.maybe_optimize(Job())
    assert d.kind == "unhealthy"
    assert not ctl.latency_obs          # poisoned samples not tracked
