"""Optimizers, schedules, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # offline env: fixed-seed fallback below
    HAVE_HYPOTHESIS = False

from repro.config import OptimizerConfig
from repro.optim import (clip_by_global_norm, global_norm, make_optimizer,
                         make_schedule)
from repro.optim.compression import (compress_tree, decompress_tree,
                                     init_residual)


def test_adamw_minimizes_quadratic():
    opt_cfg = OptimizerConfig(lr=0.05, weight_decay=0.0, warmup_steps=1,
                              total_steps=300, schedule="constant")
    opt = make_optimizer(opt_cfg)
    target = jnp.array([[1.0, -2.0], [3.0, 0.5]])
    params = {"w": jnp.zeros((2, 2))}
    state = opt.init(params)
    step = jnp.zeros((), jnp.int32)
    for i in range(300):
        grads = {"w": 2 * (params["w"] - target)}
        params, state = opt.update(grads, state, params, step + i)
    assert float(jnp.max(jnp.abs(params["w"] - target))) < 0.05


def test_adamw_bf16_state_dtype():
    opt = make_optimizer(OptimizerConfig(state_dtype="bfloat16"))
    params = {"w": jnp.ones((4, 4))}
    st_ = opt.init(params)
    assert st_["m"]["w"].dtype == jnp.bfloat16


def test_grad_clip():
    tree = {"a": jnp.full((10,), 10.0)}
    clipped, gn = clip_by_global_norm(tree, 1.0)
    assert float(gn) == pytest.approx(np.sqrt(1000.0))
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedule_warmup_and_decay():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    s = make_schedule(cfg)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1e-3)
    assert float(s(100)) < float(s(50)) < float(s(10))


def test_compression_roundtrip_small_error():
    rng = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(rng, (256,)) * 0.01}
    r = init_residual(g)
    q, s, r2 = compress_tree(g, r)
    rec = decompress_tree(q, s)
    err = float(jnp.max(jnp.abs(rec["w"] - g["w"])))
    assert err <= float(s["w"]) / 2 + 1e-9
    # residual holds exactly the quantization error
    np.testing.assert_allclose(np.asarray(r2["w"]),
                               np.asarray(g["w"] - rec["w"]), atol=1e-7)


def _check_compression_error_feedback_unbiased(seed, steps):
    """Property: with a CONSTANT gradient, error feedback makes the mean of
    decompressed gradients converge to the true gradient."""
    rng = np.random.default_rng(seed)
    g_true = {"w": jnp.asarray(rng.standard_normal(64).astype(np.float32))}
    r = init_residual(g_true)
    acc = jnp.zeros(64)
    for _ in range(steps):
        q, s, r = compress_tree(g_true, r)
        acc = acc + decompress_tree(q, s)["w"]
    mean = acc / steps
    # bias shrinks as 1/steps: |mean - g| <= max_residual/steps
    bound = float(jnp.max(jnp.abs(g_true["w"]))) / 127.0 * (1.0 + 2.0 / steps)
    assert float(jnp.max(jnp.abs(mean - g_true["w"]))) <= bound + 1e-6


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 500), steps=st.integers(3, 20))
    def test_compression_error_feedback_unbiased(seed, steps):
        _check_compression_error_feedback_unbiased(seed, steps)
else:
    @pytest.mark.parametrize("seed,steps", [
        (0, 3), (1, 5), (7, 8), (42, 13), (123, 17), (500, 20)])
    def test_compression_error_feedback_unbiased(seed, steps):
        _check_compression_error_feedback_unbiased(seed, steps)
